"""resnet_tiny — the first *branching* workload (DESIGN.md §Graph).

A CIFAR-10-scale ResNet with **two residual joins**, built through the
graph IR (`repro.graph`) rather than a flat layer list — the topology the
paper's sequential compiler could not express and the YOLO-NAS follow-up
needs:

  stem    conv 3→16  k3 same + ReLU + max-pool 2×2      (1,3,32,32) → (1,16,16,16)
  block1  conv 16→16 k3 same + ReLU                     (branch, multi-chunk)
          conv 16→16 k3 same, **add(stem out)** + ReLU  → (1,16,16,16)
  mid     conv 16→32 k3 same + ReLU + max-pool 2×2      → (1,32,8,8)
  block2  conv 32→32 k3 same + ReLU                     (branch)
          conv 32→32 k3 same, **add(mid out)**  + ReLU  → (1,32,8,8)
  head    flatten + fc 2048→10                          → (1,10) logits

Both joins close on the VTA itself: the skip activation is ACC-loaded
beside the GEMM result and merged by an ALU vector-vector ADD (DESIGN.md
§Graph) — never a host-side numpy add.  Block 1's conv matrices are
256×144 (2304 INP vectors against the 2048-vector buffer), so its layers
— including the residual one, with its halved per-chunk ACC budget — are
multi-chunk *by construction*.

The bit-exact integer reference is the graph evaluation itself
(:func:`repro.graph.evaluate_graph`): one semantics shared by the
planner, the lowering and the tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph import (Graph, GraphBuilder, compile_graph,
                         evaluate_graph)

# The linear (conv/fc) nodes of the topology, in order.
LINEAR_NODES = ("stem", "b1a", "b1b", "mid", "b2a", "b2b", "head")


@dataclasses.dataclass
class ResnetTinyWeights:
    stem_w: np.ndarray    # (16, 3, 3, 3)   int8
    stem_b: np.ndarray    # (16,)           int32
    b1a_w: np.ndarray     # (16, 16, 3, 3)
    b1a_b: np.ndarray
    b1b_w: np.ndarray     # (16, 16, 3, 3)
    b1b_b: np.ndarray
    mid_w: np.ndarray     # (32, 16, 3, 3)
    mid_b: np.ndarray
    b2a_w: np.ndarray     # (32, 32, 3, 3)
    b2a_b: np.ndarray
    b2b_w: np.ndarray     # (32, 32, 3, 3)
    b2b_b: np.ndarray
    head_w: np.ndarray    # (2048, 10)
    head_b: np.ndarray


def resnet_tiny_random_weights(seed: int = 0,
                               scale: int = 6) -> ResnetTinyWeights:
    """Deterministic int8 weights in a narrow range (static power-of-2
    requant keeps every activation healthy, as for the CIFAR CNN)."""
    rng = np.random.default_rng(seed)
    w = lambda *s: rng.integers(-scale, scale + 1, s,
                                dtype=np.int64).astype(np.int8)
    b = lambda n: rng.integers(-64, 65, (n,), dtype=np.int64).astype(np.int32)
    return ResnetTinyWeights(
        stem_w=w(16, 3, 3, 3), stem_b=b(16),
        b1a_w=w(16, 16, 3, 3), b1a_b=b(16),
        b1b_w=w(16, 16, 3, 3), b1b_b=b(16),
        mid_w=w(32, 16, 3, 3), mid_b=b(32),
        b2a_w=w(32, 32, 3, 3), b2a_b=b(32),
        b2b_w=w(32, 32, 3, 3), b2b_b=b(32),
        head_w=w(2048, 10), head_b=b(10),
    )


def _basic_block(bld: GraphBuilder, name: str, x: str, wa, ba, wb, bb,
                 wexp) -> str:
    """conv+ReLU, conv, on-VTA residual add of ``x``, ReLU — the classic
    pre-downsample ResNet basic block (requants planned by the pass)."""
    v = bld.conv(f"{name}a", x, wa, ba, padding=1,
                 weight_exp=wexp(f"{name}a"))
    v = bld.relu(f"{name}a_r", v)
    v = bld.requant(f"{name}a_q", v)
    v = bld.conv(f"{name}b", v, wb, bb, padding=1,
                 weight_exp=wexp(f"{name}b"))
    v = bld.requant(f"{name}b_q", v)
    v = bld.add(f"{name}_join", v, x)
    v = bld.relu(f"{name}_r", v)
    return bld.requant(f"{name}_q", v)


def build_resnet_tiny(weights: ResnetTinyWeights,
                      weight_exps: Optional[Dict[str, int]] = None) -> Graph:
    """The resnet_tiny DAG (unplanned requants; ≥2 residual joins).

    ``weight_exps`` maps linear-node name → the fixed-point scale of its
    int8 weights (see :func:`calibrate_weight_exps`); the requant planner
    uses it to equalise the two branch joins in *real* feature scale.
    """
    wexp = lambda n: (weight_exps or {}).get(n, 0)
    bld = GraphBuilder("resnet_tiny")
    x = bld.input("image", shape=(1, 3, 32, 32))
    v = bld.conv("stem", x, weights.stem_w, weights.stem_b, padding=1,
                 weight_exp=wexp("stem"))
    v = bld.relu("stem_r", v)
    v = bld.pool("stem_p", v, "max2x2")
    v = bld.requant("stem_q", v)
    v = _basic_block(bld, "b1", v, weights.b1a_w, weights.b1a_b,
                     weights.b1b_w, weights.b1b_b, wexp)
    v = bld.conv("mid", v, weights.mid_w, weights.mid_b, padding=1,
                 weight_exp=wexp("mid"))
    v = bld.relu("mid_r", v)
    v = bld.pool("mid_p", v, "max2x2")
    v = bld.requant("mid_q", v)
    v = _basic_block(bld, "b2", v, weights.b2a_w, weights.b2a_b,
                     weights.b2b_w, weights.b2b_b, wexp)
    v = bld.flatten("flat", v)
    v = bld.fc("head", v, weights.head_w, weights.head_b,
               weight_exp=wexp("head"))
    v = bld.requant("head_q", v)
    bld.output(v)
    return bld.build()


def calibrate_weight_exps(weights: ResnetTinyWeights,
                          calib: Sequence[np.ndarray], *,
                          margin: int = 1) -> Dict[str, int]:
    """Per-conv fixed-point weight scales from a calibration pass.

    Random int8 weights amplify (a k3 conv over 16 channels gains ~2^5),
    so with ``weight_exp = 0`` the raw-integer skip of a residual block
    sits many octaves above its branch and the join planner would
    rightly shift it to nothing.  Real quantised CNNs absorb that gain
    into the *weight scale*: we calibrate each linear node's
    ``weight_exp`` to its planned requant shift (a plan over a throwaway
    graph), which normalises every post-requant activation to scale ≈ 0
    — the trained-network situation the blueprint's two-operand ALU was
    designed for.  The b2 block then deliberately keeps one octave of
    gain per conv (``- 1``), so its join operands land two scales apart
    and the planner must equalise with a genuine on-device pre-shift.

    Delegates to the model-agnostic
    :func:`repro.quantize.ptq.calibrate_integer_weight_exps` (imported
    lazily so models/ does not pull the quantize stack at import time).
    """
    from repro.quantize.ptq import calibrate_integer_weight_exps
    return calibrate_integer_weight_exps(
        lambda: build_resnet_tiny(weights), calib, LINEAR_NODES,
        margin=margin, octave_keep=("b2a", "b2b"))


def synthetic_image(seed: int = 0) -> np.ndarray:
    """A deterministic 3×32×32 int8 test image (centred dynamic range)."""
    rng = np.random.default_rng(seed)
    return rng.integers(-64, 64, (1, 3, 32, 32),
                        dtype=np.int64).astype(np.int8)


def compile_resnet_tiny(weights: Optional[ResnetTinyWeights] = None, *,
                        calib_seeds: Sequence[int] = range(1, 9),
                        input_seed: int = 0, margin: int = 1):
    """Build + plan + compile resnet_tiny; returns ``(net, graph)``.

    Two-phase §4.2 calibration: first the weight scales
    (:func:`calibrate_weight_exps`), then the requant/pre-shift plan over
    the final graph.  The returned graph carries the planned shifts, so
    :func:`repro.graph.evaluate_graph` on it *is* the bit-exact integer
    reference for the compiled network."""
    weights = weights or resnet_tiny_random_weights()
    calib = [synthetic_image(s) for s in calib_seeds]
    wexps = calibrate_weight_exps(weights, calib, margin=margin)
    graph = build_resnet_tiny(weights, wexps)
    net = compile_graph(graph, synthetic_image(input_seed),
                        calib=calib + [synthetic_image(input_seed)],
                        margin=margin)
    return net, graph


def reference_forward_int8(graph: Graph, image: np.ndarray) -> np.ndarray:
    """Bit-exact integer logits for a *planned* graph (the semantics the
    VTA execution must reproduce)."""
    vals = evaluate_graph(graph, np.asarray(image).astype(np.int64))
    return vals[graph.outputs[0]].astype(np.int8)
