"""Parameter descriptor trees.

Every model module declares its parameters once as a pytree of
:class:`ParamDef` (shape + logical sharding axes + initialiser).  The tree
then materialises three ways:

* ``init_params``     — real arrays (smoke tests, examples, training);
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod
  dry-run lowers the full 340B/398B configs without allocating a byte);
* ``logical_tree``    — the logical-axis tuples that
  ``parallel.sharding.spec_tree`` resolves against a concrete mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # None → 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key: jax.Array, dtype=jnp.bfloat16):
    """Materialise real parameter arrays (deterministic per-leaf folding)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    arrays = []
    for i, d in enumerate(leaves):
        if d.init == "zeros":
            arrays.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            arrays.append(jnp.ones(d.shape, dtype))
        else:
            k = jax.random.fold_in(key, i)
            scale = d.scale
            if scale is None:
                fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[-1]
                scale = 1.0 / np.sqrt(max(1, fan_in))
            arrays.append(
                (jax.random.normal(k, d.shape, jnp.float32) * scale
                 ).astype(dtype))
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — zero allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def)


def logical_tree(defs):
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=_is_def))


def param_bytes(defs, bytes_per_param: int = 2) -> int:
    return param_count(defs) * bytes_per_param
