"""Core neural layers (pure JAX, config-driven, sharding-annotated).

Norms, rotary embeddings, GQA attention (double-chunked online-softmax —
the pure-JAX twin of the Pallas flash kernel, used by every lowering path),
and the MLP family (SwiGLU / GeGLU / squared-ReLU / GELU).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import RULES, logical_to_spec
from .config import ModelConfig
from .params import ParamDef

NEG_INF = -1e30


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes.

    No-op without a mesh; mesh axes that do not evenly divide the
    corresponding dimension are dropped (so the same model code lowers for
    any batch/seq size — e.g. batch=1 long-context decode)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or not mesh.axis_names:
        return x
    spec = logical_to_spec(logical, mesh)
    fixed = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        size = 1
        for a in axes:
            if dim % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        fixed.append(tuple(keep) if len(keep) > 1
                     else (keep[0] if keep else None))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def constrain_seq(x: jax.Array) -> jax.Array:
    """Sequence-parallel residual-stream constraint: (B, S, d) sharded
    ("batch", "seq", None).  This is what bounds the remat-saved scan carry
    to (B·S/|batch|/|model|)·d per device on the deep configs."""
    return constrain(x, "batch", "seq", None)


@jax.custom_vjp
def grad_cast(x: jax.Array) -> jax.Array:
    """Identity whose COTANGENT is cast to x's dtype (bf16 boundary for
    gradients leaving an f32 softmax/norm region — without it the f32
    attention cotangents flow into the projection backward dots and the
    TP psums carry f32 instead of bf16; §Perf iteration 'gradcast')."""
    return x


def _gc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)     # dtype token (a valid JAX type)


def _gc_bwd(token, ct):
    return (ct.astype(token.dtype),)


grad_cast.defvjp(_gc_fwd, _gc_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros")}
    return {"scale": ParamDef((d,), (None,), init="ones")}


def norm_apply(p, cfg: ModelConfig, x: jax.Array, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) \
            + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, D) rotate-half RoPE at absolute ``positions`` (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # (S, half)
    cos = jnp.cos(ang)[None, None]
    sin = jnp.sin(ang)[None, None]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf1 * sin + xf2 * cos], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Double-chunked attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0, q_chunk: int = 512,
                      kv_chunk: int = 512,
                      causal_skip: bool = False) -> jax.Array:
    """Online-softmax attention, O(q_chunk·kv_chunk) live scores.

    q (B, H, Sq, D); k/v (B, KV, Skv, D); GQA expands the KV *chunk* only.
    ``causal_skip`` (§Perf) drops fully-masked (q, kv) chunk pairs from the
    schedule instead of masking them — ~2× fewer attention FLOPs at long S.
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv

    def pick(n, target):
        """Largest divisor of n that is ≤ target (whisper's 1500-frame
        encoder and other non-power-of-2 lengths)."""
        c = min(target, n)
        while n % c:
            c -= 1
        return c

    q_chunk = pick(sq, q_chunk)
    kv_chunk = pick(skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = d ** -0.5

    qc = q.reshape(b, h, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def kv_step(carry, inputs, qi_pos):
        m, l, acc = carry
        kj, vj, kj_idx = inputs
        kj = jnp.repeat(kj, group, axis=1)           # (B, H, ck, D)
        vj = jnp.repeat(vj, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi_pos["q"], kj,
                       preferred_element_type=jnp.float32) * scale
        qpos = qi_pos["pos"][:, None]                       # (cq, 1)
        kpos = kj_idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum(-1, keepdims=True)
        # P·V in the input dtype (bf16 on TPU) with f32 accumulation —
        # flash-kernel discipline; halves the P/V dot traffic (§Perf C1)
        acc_new = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    def q_block(qi, q_i):
        pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        init = (jnp.full((b, h, q_chunk, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, h, q_chunk, 1), jnp.float32),
                jnp.zeros((b, h, q_chunk, d), jnp.float32))
        qi_pos = {"q": q_i, "pos": pos}
        skippable = causal_skip and causal and q_offset == 0
        if skippable:
            # §Perf: only kv chunks j ∈ [lo, qi] contribute — causality
            # bounds the top, a sliding window additionally bounds the
            # bottom (gemma3 local layers: 2 of 64 chunks live).  Static
            # slicing isn't possible (bounds depend on qi), so use a
            # fori_loop with dynamic chunk indexing.
            if window is not None:
                lo = jnp.maximum(
                    0, (qi * q_chunk - (window - 1)) // kv_chunk)
            else:
                lo = 0

            def body(j, carry):
                kj = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
                vj = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
                carry, _ = kv_step(carry, (kj, vj, j), qi_pos)
                return carry
            m, l, acc = jax.lax.fori_loop(lo, qi + 1, body, init)
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, x: kv_step(c, x, qi_pos), init,
                (kc, vc, jnp.arange(nk)))
        safe = jnp.where(l == 0.0, 1.0, l)
        return (acc / safe).astype(q.dtype)

    if nq == 1:
        out = q_block(0, qc[0])
    else:
        outs = jax.lax.map(lambda args: q_block(args[0], args[1]),
                           (jnp.arange(nq), qc))
        out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, d)
    return out


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, *, cross: bool = False
                   ) -> Dict[str, ParamDef]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, kv * hd), ("fsdp", "tp")),
        "wv": ParamDef((d, kv * hd), ("fsdp", "tp")),
        "wo": ParamDef((h * hd, d), ("tp", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((h * hd,), ("tp",), init="zeros")
        defs["bk"] = ParamDef((kv * hd,), ("tp",), init="zeros")
        defs["bv"] = ParamDef((kv * hd,), ("tp",), init="zeros")
    return defs


def attention_qkv(p, cfg: ModelConfig, x: jax.Array,
                  kv_input: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project to (q, k, v) with head layout (B, H, S, D)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_in = x if kv_input is None else kv_input
    skv = kv_in.shape[1]
    q = x @ p["wq"]
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, skv, kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, kv, hd).transpose(0, 2, 1, 3)
    q = constrain(q, "batch", "tp", None, None)
    return q, k, v


def attention_apply(p, cfg: ModelConfig, x: jax.Array, *,
                    kind: str = "attn", positions: Optional[jax.Array] = None,
                    kv_input: Optional[jax.Array] = None,
                    causal: bool = True, q_offset: int = 0) -> jax.Array:
    """Full attention block: qkv → rope → chunked flash → output proj.

    kind: 'attn' (global) | 'attn_local' | 'attn_swa' — selects window and
    (for gemma3) the RoPE theta.  ``kv_input`` switches to cross-attention
    (no RoPE on kv, non-causal).
    """
    b, s, _ = x.shape
    q, k, v = attention_qkv(p, cfg, x, kv_input)
    window = None
    theta = cfg.rope_theta
    if kind == "attn_local":
        window = cfg.local_window
    elif kind == "attn_swa":
        window = cfg.local_window
    elif kind == "attn" and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global
    is_cross = kv_input is not None
    if not is_cross:
        if positions is None:
            positions = q_offset + jnp.arange(s)
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    # bf16 gradient boundary around the f32 softmax region (§Perf)
    q, k, v = grad_cast(q), grad_cast(k), grad_cast(v)
    out = chunked_attention(
        q, k, v, causal=causal and not is_cross,
        window=None if is_cross else window, q_offset=q_offset,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        causal_skip=cfg.causal_skip)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"]
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# MLP family
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> Dict[str, ParamDef]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {"wg": ParamDef((d, ff), ("fsdp", "tp")),
                "wu": ParamDef((d, ff), ("fsdp", "tp")),
                "wd": ParamDef((ff, d), ("tp", "fsdp"))}
    return {"wu": ParamDef((d, ff), ("fsdp", "tp")),
            "wd": ParamDef((ff, d), ("tp", "fsdp"))}


def mlp_apply(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        hmid = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif cfg.act == "geglu":
        hmid = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])
    elif cfg.act == "sq_relu":
        r = jnp.maximum(x @ p["wu"], 0)
        hmid = r * r                       # nemotron squared-ReLU
    else:
        hmid = jax.nn.gelu(x @ p["wu"], approximate=True)
    hmid = constrain(hmid, "batch", None, "tp")
    return constrain(hmid @ p["wd"], "batch", None, None)
