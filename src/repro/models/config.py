"""Unified architecture configuration covering all 10 assigned archs.

One ``ModelConfig`` describes dense transformers, GQA/MQA variants, MoE,
RWKV-6, Mamba hybrids, encoder-decoder (whisper) and stub-fronted VLM/audio
models.  Per-layer behaviour comes from ``layer_schedule()`` which expands
the declarative schedule fields into a per-layer kind list.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # apply MoE every `period` layers (jamba: 2 → alternate dense/MoE)
    period: int = 1
    n_shared_experts: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # default d_model // n_heads

    # layer schedule
    attn_kind: str = "global"             # global | local_global | swa
    local_window: int = 1024              # window for local / swa layers
    local_ratio: int = 0                  # gemma3: N local per 1 global
    ssm_kind: Optional[str] = None        # None | "rwkv6" | "mamba"
    ssm_ratio: int = 0                    # jamba: N ssm per 1 attn

    # blocks
    act: str = "swiglu"                   # swiglu | geglu | sq_relu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None   # gemma3: 1e6 on global layers
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None

    # mamba (hybrid) geometry
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4

    # rwkv geometry
    rwkv_head_dim: int = 64

    # encoder-decoder (whisper)
    encoder_layers: int = 0               # 0 → decoder-only
    encoder_seq: int = 1500               # precomputed frame embeddings

    # modality frontends (STUB per assignment: precomputed embeddings)
    frontend: Optional[str] = None        # None | "audio" | "vision"
    frontend_prefix: int = 0              # patch/frame prefix length in seq

    # execution policy
    remat: str = "full"                   # none | dots | full
    scan_layers: bool = True              # lax.scan over layer stack
    q_chunk: int = 512
    kv_chunk: int = 512
    causal_skip: bool = False             # §Perf: skip fully-masked kv chunks

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def vocab_padded(self) -> int:
        """Embedding-table size: vocab rounded up to a 256 multiple so the
        vocab axis shards evenly over any production mesh (whisper's 51865
        and internvl's 92553 are not 16-divisible).  Logits beyond
        ``vocab`` are masked in ``unembed_logits``."""
        return ((self.vocab + 255) // 256) * 256

    # ------------------------------------------------------------------
    def layer_schedule(self) -> Tuple[str, ...]:
        """Per-layer kinds: 'attn' | 'attn_local' | 'attn_swa' | 'rwkv6'
        | 'mamba' (decoder stack)."""
        kinds = []
        for i in range(self.n_layers):
            if self.ssm_kind and self.ssm_ratio:
                # jamba: 1 attn per (ssm_ratio+1) layers, attn in the middle
                pos = i % (self.ssm_ratio + 1)
                if pos == self.ssm_ratio // 2:
                    kinds.append("attn")
                else:
                    kinds.append(self.ssm_kind)
            elif self.ssm_kind:
                kinds.append(self.ssm_kind)
            elif self.attn_kind == "swa":
                kinds.append("attn_swa")
            elif self.attn_kind == "local_global" and self.local_ratio:
                # gemma3: `local_ratio` local layers then 1 global
                kinds.append("attn_local"
                             if (i % (self.local_ratio + 1)) < self.local_ratio
                             else "attn")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def moe_layers(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        return tuple((i % self.moe.period) == self.moe.period - 1
                     for i in range(self.n_layers))

    @property
    def uniform_stack(self) -> bool:
        """True when every decoder layer is identical (enables scan-over-
        layers with stacked params)."""
        return (len(set(self.layer_schedule())) == 1
                and len(set(self.moe_layers())) == 1)

    # ------------------------------------------------------------------
    def param_count_estimate(self) -> int:
        """Analytic parameter count (embeddings + layers), for 6·N·D."""
        d, v = self.d_model, self.vocab
        hd = self.head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        sched = self.layer_schedule()
        moe_layers = self.moe_layers()
        for kind, is_moe in zip(sched, moe_layers):
            if kind.startswith("attn"):
                total += d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd)
                total += self.n_heads * hd * d
            elif kind == "mamba":
                di = self.mamba_expand * d
                total += 2 * d * di + di * d + di * (2 * self.mamba_d_state + 1)
                total += di * self.mamba_conv
            elif kind == "rwkv6":
                total += 4 * d * d + d * d          # r,k,v,g,o
                total += 2 * d * self.d_ff          # channel mix
                continue                            # no separate FFN
            if is_moe and self.moe is not None:
                n_ff = 3 if self.act in ("swiglu", "geglu") else 2
                total += (self.moe.n_experts *
                          n_ff * d * self.moe.d_ff_expert)
                total += d * self.moe.n_experts     # router
            else:
                n_ff = 3 if self.act in ("swiglu", "geglu") else 2
                total += n_ff * d * self.d_ff
        if self.encoder_layers:
            # encoder self-attn + FFN, decoder cross-attn
            enc = self.encoder_layers * (
                4 * d * self.n_heads * hd + 2 * d * self.d_ff)
            cross = self.n_layers * (4 * d * self.n_heads * hd)
            total += enc + cross
        return total

    def active_param_count_estimate(self) -> int:
        """MoE: experts scaled by top_k/n_experts (for 6·N_active·D)."""
        if self.moe is None:
            return self.param_count_estimate()
        dense = dataclasses.replace(self, moe=None)
        base = dense.param_count_estimate()
        n_ff = 3 if self.act in ("swiglu", "geglu") else 2
        dense_ffn = sum(n_ff * self.d_model * self.d_ff
                        for m in self.moe_layers() if not m)
        # subtract the dense FFNs counted for MoE layers, add active experts
        moe_count = sum(1 for m in self.moe_layers() if m)
        base -= moe_count * n_ff * self.d_model * self.d_ff
        base += moe_count * (self.moe.top_k + self.moe.n_shared_experts) \
            * n_ff * self.d_model * self.moe.d_ff_expert
        return base
