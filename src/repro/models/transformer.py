"""Model assembly: decoder-only LMs, hybrids and encoder-decoders.

The layer stack is expressed as (pattern × repeats) + tail: the smallest
repeating period of the per-layer schedule is detected, parameters for each
pattern position are *stacked* over the repeats, and the forward pass scans
over the repeats (one trace of the pattern regardless of depth — a 96-layer
dense model lowers as a single 1-layer trace).  Non-periodic tails apply as
individual layers.  ``jax.checkpoint`` wraps the scanned body per the
config's remat policy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention_apply, attention_defs, constrain,
                     constrain_seq, mlp_apply, mlp_defs, norm_apply,
                     norm_defs)
from .mamba import mamba_apply, mamba_defs
from .moe import moe_apply, moe_defs
from .params import ParamDef
from .rwkv6 import (rwkv6_channel_mix, rwkv6_defs, rwkv6_time_mix)


# ---------------------------------------------------------------------------
# Schedule → (pattern, repeats, tail)
# ---------------------------------------------------------------------------

def schedule_items(cfg: ModelConfig) -> List[Tuple[str, bool]]:
    return list(zip(cfg.layer_schedule(), cfg.moe_layers()))


def find_period(items: List[Tuple[str, bool]]) -> Tuple[int, int, int]:
    """Smallest (period, repeats, tail) with items = pattern×repeats + tail
    and repeats ≥ 1."""
    n = len(items)
    for p in range(1, n + 1):
        reps = n // p
        body = items[:reps * p]
        if all(body[i] == body[i % p] for i in range(len(body))):
            tail_start = reps * p
            if all(items[tail_start + j] == items[j]
                   for j in range(n - tail_start)):
                return p, reps, n - tail_start
    return n, 1, 0


# ---------------------------------------------------------------------------
# Per-layer defs
# ---------------------------------------------------------------------------

def block_defs(cfg: ModelConfig, kind: str, is_moe: bool, *,
               cross: bool = False) -> Dict[str, Any]:
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg)}
    if kind.startswith("attn"):
        defs["mix"] = attention_defs(cfg)
    elif kind == "rwkv6":
        defs["mix"] = rwkv6_defs(cfg)
    elif kind == "mamba":
        defs["mix"] = mamba_defs(cfg)
    else:
        raise ValueError(kind)
    if cross:
        defs["norm_x"] = norm_defs(cfg)
        defs["cross"] = attention_defs(cfg, cross=True)
    defs["norm2"] = norm_defs(cfg)
    if kind != "rwkv6":                       # rwkv6 carries its channel mix
        defs["ffn"] = moe_defs(cfg) if is_moe else mlp_defs(cfg)
    return defs


def block_apply(bp, cfg: ModelConfig, h: jax.Array, kind: str, is_moe: bool,
                *, enc_out: Optional[jax.Array] = None, q_offset: int = 0
                ) -> Tuple[jax.Array, jax.Array]:
    """One decoder layer; returns (h, moe_aux_scalar)."""
    aux = jnp.zeros((), jnp.float32)
    hin = norm_apply(bp["norm1"], cfg, h)
    if kind.startswith("attn"):
        mix = attention_apply(bp["mix"], cfg, hin, kind=kind,
                              q_offset=q_offset)
    elif kind == "rwkv6":
        mix = rwkv6_time_mix(bp["mix"], cfg, hin)
    elif kind == "mamba":
        mix = mamba_apply(bp["mix"], cfg, hin)
    else:
        raise ValueError(kind)
    h = h + mix
    if enc_out is not None and "cross" in bp:
        hx = norm_apply(bp["norm_x"], cfg, h)
        h = h + attention_apply(bp["cross"], cfg, hx, kv_input=enc_out,
                                causal=False)
    hf = norm_apply(bp["norm2"], cfg, h)
    if kind == "rwkv6":
        h = h + rwkv6_channel_mix(bp["mix"], cfg, hf)
    elif is_moe:
        out, moe_aux = moe_apply(bp["ffn"], cfg, hf)
        h = h + out
        aux = aux + moe_aux["load_balance"] + 1e-3 * moe_aux["router_z"]
    else:
        h = h + mlp_apply(bp["ffn"], cfg, hf)
    return constrain_seq(h), aux


# ---------------------------------------------------------------------------
# Whole-model defs
# ---------------------------------------------------------------------------

def _stack_defs(defs, k: int):
    return jax.tree.map(
        lambda d: ParamDef((k,) + d.shape, (None,) + d.logical,
                           init=d.init, scale=d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    items = schedule_items(cfg)
    cross = cfg.encoder_layers > 0
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_padded, d), ("tp", "fsdp"), scale=1.0),
        "final_norm": norm_defs(cfg),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, cfg.vocab_padded), ("fsdp", "tp"))

    if cfg.scan_layers:
        p, reps, tail = find_period(items)
    else:
        p, reps, tail = len(items), 1, 0
    if reps > 1:
        defs["blocks"] = [
            _stack_defs(block_defs(cfg, kind, moe, cross=cross), reps)
            for kind, moe in items[:p]]
        defs["tail"] = [block_defs(cfg, kind, moe, cross=cross)
                        for kind, moe in items[p * reps:]]
    else:
        defs["blocks"] = []
        defs["tail"] = [block_defs(cfg, kind, moe, cross=cross)
                        for kind, moe in items]

    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, qkv_bias=False)
        enc_block = {
            "norm1": norm_defs(cfg), "mix": attention_defs(enc_cfg),
            "norm2": norm_defs(cfg), "ffn": mlp_defs(cfg),
        }
        defs["encoder"] = {
            "pos": ParamDef((cfg.encoder_seq, d), (None, "fsdp"), scale=0.02),
            "blocks": _stack_defs(enc_block, cfg.encoder_layers),
            "final_norm": norm_defs(cfg),
        }
    return defs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (the conv
    frontend is a STUB per the assignment).  frames (B, T, d)."""
    enc = params["encoder"]
    h = frames + enc["pos"][None, :frames.shape[1]]
    h = constrain(h, "batch", None, None)
    enc_cfg = dataclasses.replace(cfg, qkv_bias=False)

    def body(h, bp):
        hin = norm_apply(bp["norm1"], cfg, h)
        h = h + attention_apply(bp["mix"], enc_cfg, hin, causal=False)
        hf = norm_apply(bp["norm2"], cfg, h)
        h = h + mlp_apply(bp["ffn"], cfg, hf)
        return h, None

    h, _ = jax.lax.scan(_remat_wrap(body, cfg), h, enc["blocks"])
    return norm_apply(enc["final_norm"], cfg, h)


def forward(params, cfg: ModelConfig, tokens: jax.Array, *,
            prefix_embed: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None,
            q_offset: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Token ids (B, S) [+ optional modality prefix embeddings (B, P, d)]
    → (hidden states (B, S(+P), d), moe aux loss scalar)."""
    items = schedule_items(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h.astype(params["embed"].dtype)
    if prefix_embed is not None:
        h = jnp.concatenate([prefix_embed.astype(h.dtype), h], axis=1)
    h = constrain_seq(h)

    aux_total = jnp.zeros((), jnp.float32)
    n_blocks = len(params["blocks"])
    if n_blocks:
        pattern = items[:n_blocks]

        def body(carry, bp_slice):
            h, aux = carry
            for pos, (kind, moe) in enumerate(pattern):
                h, a = block_apply(bp_slice[pos], cfg, h, kind, moe,
                                   enc_out=enc_out, q_offset=q_offset)
                aux = aux + a
            return (h, aux), None

        (h, aux_total), _ = jax.lax.scan(
            _remat_wrap(body, cfg), (h, aux_total), params["blocks"])
        tail_items = items[-len(params["tail"]):] if params["tail"] else []
    else:
        tail_items = items

    for bp, (kind, moe) in zip(params["tail"], tail_items):
        fn = _remat_wrap(
            lambda h, bp=bp, kind=kind, moe=moe: block_apply(
                bp, cfg, h, kind, moe, enc_out=enc_out, q_offset=q_offset),
            cfg)
        h, a = fn(h)
        aux_total = aux_total + a

    h = norm_apply(params["final_norm"], cfg, h)
    return h, aux_total


def unembed_logits(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """h (..., d) → logits (..., vocab_padded); vocab stays TP-sharded.
    Padding columns (≥ vocab) are masked to -inf."""
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["unembed"]
    if cfg.vocab_padded != cfg.vocab:
        mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return constrain(logits, "batch", None, "vocab")
