"""resnet8 — the first *ResNet-scale* workload (DESIGN.md §Strided-lowering).

A 3-stage CIFAR-10-scale ResNet-8 built through the graph IR: the
stage-transition vocabulary (stride-2 downsampling convolutions, a
projection shortcut, a global-average-pool head) the paper's "larger CNN
architectures" claim — and the YOLO-NAS follow-up — actually require:

  stem  conv 3→16   k3 s1 p1 + ReLU                    (1,3,32,32) → (1,16,32,32)
  b1    conv 16→16  k3 p1 + ReLU                       (identity basic block,
        conv 16→16  k3 p1, **add(stem out)** + ReLU     multi-chunk by
                                                        construction) → 32×32
  t2    conv 16→32  k3 **s2** p1 + ReLU                (stage transition #1)
        conv 16→32  k2 **s2** p0                       (projection shortcut)
        conv 32→32  k3 p1, **add(projection)** + ReLU  → (1,32,16,16)
  t3    conv 32→64  k3 **s2** p1 + ReLU                (stage transition #2)
        conv 32→64  k2 **s2** p0                       (projection shortcut)
        conv 64→64  k3 p1, **add(projection)** + ReLU  → (1,64,8,8)
  head  conv 64→64  k1 + ReLU + **global_avg_pool**    → (1,64,1,1)
        flatten + fc 64→10                             → (1,10) logits

Every join closes on the VTA (ALU vector-vector ADD against the
ACC-loaded skip operand); the GAP head executes as the on-device ADD-pair
tree reduction + SHR of DESIGN.md §Strided-lowering, fused with the 1×1
mixing conv into one VTA layer.  The projection shortcuts are k2/s2
convs — they tile the input exactly (the `conv-stride-tiling` grid
constraint), unlike the torch-classic lossy 1×1/s2.

The bit-exact integer reference is the graph evaluation itself
(:func:`repro.graph.evaluate_graph`), shared by the planner, the
lowering and the tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.graph import (Graph, GraphBuilder, compile_graph,
                         evaluate_graph)

# The linear (conv/fc) nodes of the topology, in order.
LINEAR_NODES = ("stem", "b1a", "b1b", "t2a", "t2p", "t2b",
                "t3a", "t3p", "t3b", "head", "fc")


@dataclasses.dataclass
class Resnet8Weights:
    stem_w: np.ndarray    # (16, 3, 3, 3)   int8
    stem_b: np.ndarray    # (16,)           int32
    b1a_w: np.ndarray     # (16, 16, 3, 3)
    b1a_b: np.ndarray
    b1b_w: np.ndarray     # (16, 16, 3, 3)
    b1b_b: np.ndarray
    t2a_w: np.ndarray     # (32, 16, 3, 3)  stride-2 main path
    t2a_b: np.ndarray
    t2p_w: np.ndarray     # (32, 16, 2, 2)  stride-2 projection
    t2p_b: np.ndarray
    t2b_w: np.ndarray     # (32, 32, 3, 3)
    t2b_b: np.ndarray
    t3a_w: np.ndarray     # (64, 32, 3, 3)  stride-2 main path
    t3a_b: np.ndarray
    t3p_w: np.ndarray     # (64, 32, 2, 2)  stride-2 projection
    t3p_b: np.ndarray
    t3b_w: np.ndarray     # (64, 64, 3, 3)
    t3b_b: np.ndarray
    head_w: np.ndarray    # (64, 64, 1, 1)  1×1 mixing conv ahead of GAP
    head_b: np.ndarray
    fc_w: np.ndarray      # (64, 10)
    fc_b: np.ndarray


def resnet8_random_weights(seed: int = 0, scale: int = 5) -> Resnet8Weights:
    """Deterministic int8 weights in a narrow range (static power-of-2
    requant keeps every activation healthy, as for resnet_tiny)."""
    rng = np.random.default_rng(seed)
    w = lambda *s: rng.integers(-scale, scale + 1, s,
                                dtype=np.int64).astype(np.int8)
    b = lambda n: rng.integers(-64, 65, (n,), dtype=np.int64).astype(np.int32)
    return Resnet8Weights(
        stem_w=w(16, 3, 3, 3), stem_b=b(16),
        b1a_w=w(16, 16, 3, 3), b1a_b=b(16),
        b1b_w=w(16, 16, 3, 3), b1b_b=b(16),
        t2a_w=w(32, 16, 3, 3), t2a_b=b(32),
        t2p_w=w(32, 16, 2, 2), t2p_b=b(32),
        t2b_w=w(32, 32, 3, 3), t2b_b=b(32),
        t3a_w=w(64, 32, 3, 3), t3a_b=b(64),
        t3p_w=w(64, 32, 2, 2), t3p_b=b(64),
        t3b_w=w(64, 64, 3, 3), t3b_b=b(64),
        head_w=w(64, 64, 1, 1), head_b=b(64),
        fc_w=w(64, 10), fc_b=b(10),
    )


def _identity_block(bld: GraphBuilder, name: str, x: str, wa, ba, wb, bb,
                    wexp) -> str:
    """conv+ReLU, conv, on-VTA residual add of ``x``, ReLU — the classic
    same-resolution ResNet basic block."""
    v = bld.conv(f"{name}a", x, wa, ba, padding=1,
                 weight_exp=wexp(f"{name}a"))
    v = bld.relu(f"{name}a_r", v)
    v = bld.requant(f"{name}a_q", v)
    v = bld.conv(f"{name}b", v, wb, bb, padding=1,
                 weight_exp=wexp(f"{name}b"))
    v = bld.requant(f"{name}b_q", v)
    v = bld.add(f"{name}_join", v, x)
    v = bld.relu(f"{name}_r", v)
    return bld.requant(f"{name}_q", v)


def _downsample_block(bld: GraphBuilder, name: str, x: str, wa, ba, wp, bp,
                      wb, bb, wexp) -> str:
    """The stride-2 stage transition (DESIGN.md §Strided-lowering):
    k3/s2/p1 conv + ReLU, k2/s2 projection shortcut of ``x``, k3/s1 conv,
    on-VTA residual add of the projection, ReLU."""
    v = bld.conv(f"{name}a", x, wa, ba, stride=2, padding=1,
                 weight_exp=wexp(f"{name}a"))
    v = bld.relu(f"{name}a_r", v)
    v = bld.requant(f"{name}a_q", v)
    p = bld.conv(f"{name}p", x, wp, bp, stride=2,
                 weight_exp=wexp(f"{name}p"))
    p = bld.requant(f"{name}p_q", p)
    v = bld.conv(f"{name}b", v, wb, bb, padding=1,
                 weight_exp=wexp(f"{name}b"))
    v = bld.requant(f"{name}b_q", v)
    v = bld.add(f"{name}_join", v, p)
    v = bld.relu(f"{name}_r", v)
    return bld.requant(f"{name}_q", v)


def build_resnet8(weights: Resnet8Weights,
                  weight_exps: Optional[Dict[str, int]] = None) -> Graph:
    """The resnet8 DAG (unplanned requants; 3 joins, 4 stride-2 convs,
    GAP head).  ``weight_exps`` maps linear-node name → the fixed-point
    scale of its int8 weights (see :func:`calibrate_weight_exps`)."""
    wexp = lambda n: (weight_exps or {}).get(n, 0)
    bld = GraphBuilder("resnet8")
    x = bld.input("image", shape=(1, 3, 32, 32))
    v = bld.conv("stem", x, weights.stem_w, weights.stem_b, padding=1,
                 weight_exp=wexp("stem"))
    v = bld.relu("stem_r", v)
    v = bld.requant("stem_q", v)
    v = _identity_block(bld, "b1", v, weights.b1a_w, weights.b1a_b,
                        weights.b1b_w, weights.b1b_b, wexp)
    v = _downsample_block(bld, "t2", v, weights.t2a_w, weights.t2a_b,
                          weights.t2p_w, weights.t2p_b,
                          weights.t2b_w, weights.t2b_b, wexp)
    v = _downsample_block(bld, "t3", v, weights.t3a_w, weights.t3a_b,
                          weights.t3p_w, weights.t3p_b,
                          weights.t3b_w, weights.t3b_b, wexp)
    v = bld.conv("head", v, weights.head_w, weights.head_b,
                 weight_exp=wexp("head"))
    v = bld.relu("head_r", v)
    v = bld.global_avg_pool("head_gap", v)
    v = bld.requant("head_q", v)
    v = bld.flatten("flat", v)
    v = bld.fc("fc", v, weights.fc_w, weights.fc_b, weight_exp=wexp("fc"))
    v = bld.requant("fc_q", v)
    bld.output(v)
    return bld.build()


def calibrate_weight_exps(weights: Resnet8Weights,
                          calib: Sequence[np.ndarray], *,
                          margin: int = 1) -> Dict[str, int]:
    """Per-conv fixed-point weight scales from a calibration pass (the
    two-phase §4.2 discipline of resnet_tiny): each linear node's
    ``weight_exp`` is calibrated to its planned requant shift over a
    throwaway graph, normalising every post-requant activation to scale
    ≈ 0 — the trained-network situation.  The t3 branch then keeps one
    octave of gain per conv (``- 1``), so its join operands land two
    scales apart and the planner must equalise with a genuine on-device
    pre-shift over the projection operand.

    Delegates to the model-agnostic
    :func:`repro.quantize.ptq.calibrate_integer_weight_exps` (imported
    lazily so models/ does not pull the quantize stack at import time).
    """
    from repro.quantize.ptq import calibrate_integer_weight_exps
    return calibrate_integer_weight_exps(
        lambda: build_resnet8(weights), calib, LINEAR_NODES,
        margin=margin, octave_keep=("t3a", "t3b"))


def synthetic_image(seed: int = 0) -> np.ndarray:
    """A deterministic 3×32×32 int8 test image (centred dynamic range)."""
    rng = np.random.default_rng(seed)
    return rng.integers(-64, 64, (1, 3, 32, 32),
                        dtype=np.int64).astype(np.int8)


def compile_resnet8(weights: Optional[Resnet8Weights] = None, *,
                    calib_seeds: Sequence[int] = range(1, 9),
                    input_seed: int = 0, margin: int = 1,
                    schedule: str = "serialized"):
    """Build + plan + compile resnet8; returns ``(net, graph)``.

    Two-phase §4.2 calibration (weight scales, then requant/pre-shift
    planning over the final graph); the returned graph carries the
    planned shifts, so :func:`repro.graph.evaluate_graph` on it *is* the
    bit-exact integer reference for the compiled network."""
    weights = weights or resnet8_random_weights()
    calib = [synthetic_image(s) for s in calib_seeds]
    wexps = calibrate_weight_exps(weights, calib, margin=margin)
    graph = build_resnet8(weights, wexps)
    net = compile_graph(graph, synthetic_image(input_seed),
                        calib=calib + [synthetic_image(input_seed)],
                        margin=margin, schedule=schedule)
    return net, graph


def reference_forward_int8(graph: Graph, image: np.ndarray) -> np.ndarray:
    """Bit-exact integer logits for a *planned* graph (the semantics the
    VTA execution must reproduce)."""
    vals = evaluate_graph(graph, np.asarray(image).astype(np.int64))
    return vals[graph.outputs[0]].astype(np.int8)
