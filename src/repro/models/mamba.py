"""Mamba-1 selective-state-space block (for the Jamba hybrid, arXiv:2403.19887).

in_proj → causal depthwise conv → selective scan (data-dependent Δ, B, C)
→ SiLU gate → out_proj.  Training/prefill use a **chunked associative
scan** (log-depth within each chunk, recurrent carry across chunks, so the
live ``(B, L, d_inner, d_state)`` tensor is bounded by the chunk length);
decode is a single-step state update (O(1) memory — ``long_500k``).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import constrain
from .params import ParamDef

MAMBA_CHUNK = 128


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    r = _dt_rank(cfg)
    return {
        "in_proj": ParamDef((d, 2 * di), ("fsdp", "tp")),
        "conv_w": ParamDef((di, cfg.mamba_conv), ("tp", None), scale=0.5),
        "conv_b": ParamDef((di,), ("tp",), init="zeros"),
        "x_proj": ParamDef((di, r + 2 * ds), ("tp", None)),
        "dt_w": ParamDef((r, di), (None, "tp")),
        "dt_bias": ParamDef((di,), ("tp",), init="ones"),
        "A_log": ParamDef((di, ds), ("tp", None), init="ones"),
        "D": ParamDef((di,), ("tp",), init="ones"),
        "out_proj": ParamDef((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d.  x (B,S,di); w (di,K); prev (B,K-1,di)."""
    _, s, di = x.shape
    k = w.shape[1]
    pad = (jnp.zeros((x.shape[0], k - 1, di), x.dtype)
           if prev is None else prev.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+K-1, di)
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.T[:, None, :].astype(jnp.float32),                 # (K, 1, di)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=di)
    return (out + b.astype(jnp.float32)).astype(x.dtype)[:, :s]


def _ssm_params(p, cfg: ModelConfig, xc: jax.Array):
    """xc (B,S,di) → (decay (B,S,di,ds), Bx (B,S,di,ds), C (B,S,ds))."""
    ds = cfg.mamba_d_state
    r = _dt_rank(cfg)
    proj = xc @ p["x_proj"]                                  # (B,S,r+2ds)
    dt, Bc, Cc = jnp.split(proj, [r, r + ds], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_w"] + p["dt_bias"]).astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di, ds)
    decay = jnp.exp(dt[..., None] * A[None, None])           # (B,S,di,ds)
    Bx = (dt * xc.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, :, None, :]              # (B,S,di,ds)
    return decay, Bx, Cc.astype(jnp.float32)


def _scan_chunk(decay, bx, h0):
    """Associative scan within one chunk; h0 (B,di,ds) carry."""
    def combine(a, b):
        (da, xa), (db, xb) = a, b
        return (da * db, xb + db * xa)
    d_cum, x_cum = jax.lax.associative_scan(
        combine, (decay, bx), axis=1)
    h = x_cum + d_cum * h0[:, None]                          # inject carry
    return h, h[:, -1]


def mamba_apply(p, cfg: ModelConfig, x: jax.Array, *,
                state: Optional[Tuple[jax.Array, jax.Array]] = None,
                return_state: bool = False):
    """x (B,S,d) → (B,S,d).  state = (conv_tail (B,K-1,di), h (B,di,ds))."""
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    conv_prev, h_prev = (None, None) if state is None else state

    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "tp")
    xc = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"], conv_prev)
                     .astype(jnp.float32)).astype(x.dtype)

    decay, bx, cc = _ssm_params(p, cfg, xc)

    chunk = min(MAMBA_CHUNK, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if h_prev is None:
        h_prev = jnp.zeros((b, di, ds), jnp.float32)

    dec_c = decay.reshape(b, nc, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    bx_c = bx.reshape(b, nc, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    cc_c = cc.reshape(b, nc, chunk, ds).transpose(1, 0, 2, 3)
    xc_c = xc.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)

    def step(h0, inp):
        dc, bc, ccc, xcc = inp
        hh, h_last = _scan_chunk(dc, bc, h0)
        y = jnp.einsum("blds,bls->bld", hh, ccc)             # (B,L,di)
        y = y + p["D"].astype(jnp.float32) * xcc.astype(jnp.float32)
        return h_last, y

    h_final, ys = jax.lax.scan(step, h_prev, (dec_c, bx_c, cc_c, xc_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = constrain(y @ p["out_proj"], "batch", None, None)
    if return_state:
        k = cfg.mamba_conv
        prev = (jnp.zeros((b, k - 1, di), x_in.dtype)
                if conv_prev is None else conv_prev.astype(x_in.dtype))
        conv_tail = jnp.concatenate([prev, x_in], 1)[:, -(k - 1):]
        return out, (conv_tail, h_final)
    return out


def mamba_decode_step(p, cfg: ModelConfig, x: jax.Array,
                      state: Tuple[jax.Array, jax.Array]):
    """Single token: x (B, d) + (conv_tail, h) → (out (B, d), new state)."""
    out, new_state = mamba_apply(p, cfg, x[:, None], state=state,
                                 return_state=True)
    return out[:, 0], new_state


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di = cfg.mamba_expand * cfg.d_model
    return (jnp.zeros((batch, cfg.mamba_conv - 1, di), dtype),
            jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32))
