"""CIFAR-10-scale CNN — the first workload past LeNet-5 (DESIGN.md §3).

A VGG-style int8 CNN sized so that layer 1 genuinely exceeds the VTA's
SRAM (the scaling step the paper's conclusion promises and the YOLO-NAS
follow-up work requires — same-padded convolutions, max pooling, and
multi-chunk matrices):

  L1 conv 3→64  k5 same-pad + ReLU + max-pool 2×2   (1,3,32,32) → (1,64,16,16)
  L2 conv 64→32 k3 same-pad + ReLU + avg-pool 2×2   → (1,32,8,8)
  L3 conv 32→64 k3 same-pad + ReLU + max-pool 2×2   → (1,64,4,4)
  L4 fc 1024→128 + ReLU
  L5 fc 128→10

Layer 1's input matrix is 1024×75 → 64×5 INP blocks = 5120 vectors, far
beyond the 2048-vector INP buffer of the default profile, so its program
is multi-chunk *by construction* and the pool/requant ALU uops are
re-indexed against each chunk's local ACC window (DESIGN.md §3).  Layer 2
is multi-chunk too (9216 INP vectors), exercising the avg-pool ADD/SHR
program across chunks.

As for LeNet-5 (``repro.models.lenet``), two references live here: the
bit-exact integer forward pass the VTA execution must reproduce, and a
float32 JAX forward standing in for a framework-trained model (torch is
not available here; recorded in DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.conv_lowering import conv2d_reference
from repro.core.layer_compiler import LayerSpec


@dataclasses.dataclass
class CifarCNNWeights:
    conv1_w: np.ndarray   # (64, 3, 5, 5)   int8
    conv1_b: np.ndarray   # (64,)           int32
    conv2_w: np.ndarray   # (32, 64, 3, 3)
    conv2_b: np.ndarray
    conv3_w: np.ndarray   # (64, 32, 3, 3)
    conv3_b: np.ndarray
    fc4_w: np.ndarray     # (1024, 128)
    fc4_b: np.ndarray
    fc5_w: np.ndarray     # (128, 10)
    fc5_b: np.ndarray


def cifar_cnn_random_weights(seed: int = 0, scale: int = 8) -> CifarCNNWeights:
    """Deterministic int8 weights in a narrow range (the static power-of-2
    requant discipline keeps activations healthy for any scale ≤ 16)."""
    rng = np.random.default_rng(seed)
    w = lambda *s: rng.integers(-scale, scale + 1, s,
                                dtype=np.int64).astype(np.int8)
    b = lambda n: rng.integers(-64, 65, (n,), dtype=np.int64).astype(np.int32)
    return CifarCNNWeights(
        conv1_w=w(64, 3, 5, 5), conv1_b=b(64),
        conv2_w=w(32, 64, 3, 3), conv2_b=b(32),
        conv3_w=w(64, 32, 3, 3), conv3_b=b(64),
        fc4_w=w(1024, 128), fc4_b=b(128),
        fc5_w=w(128, 10), fc5_b=b(10),
    )


def cifar_cnn_specs(weights: CifarCNNWeights,
                    requant_shifts: Optional[Sequence[Optional[int]]] = None
                    ) -> List[LayerSpec]:
    """The five LayerSpecs; ``requant_shifts`` pins the per-layer shifts
    (None entries = choose statically at compile time)."""
    s = list(requant_shifts) if requant_shifts is not None else [None] * 5
    return [
        LayerSpec("c1_conv", "conv", weights.conv1_w, weights.conv1_b,
                  padding=2, relu=True, pool="max2x2", requant_shift=s[0]),
        LayerSpec("c2_conv", "conv", weights.conv2_w, weights.conv2_b,
                  padding=1, relu=True, pool="avg2x2", requant_shift=s[1]),
        LayerSpec("c3_conv", "conv", weights.conv3_w, weights.conv3_b,
                  padding=1, relu=True, pool="max2x2", requant_shift=s[2]),
        LayerSpec("f4_fc", "fc", weights.fc4_w, weights.fc4_b,
                  relu=True, requant_shift=s[3]),
        LayerSpec("f5_fc", "fc", weights.fc5_w, weights.fc5_b,
                  relu=False, requant_shift=s[4]),
    ]


# ---------------------------------------------------------------------------
# Integer reference (the semantics the VTA must match bit-for-bit)
# ---------------------------------------------------------------------------

def _requant(acc: np.ndarray, pool_div: int, shift: int) -> np.ndarray:
    from repro.core.layout import truncate_int8
    return truncate_int8(acc >> (pool_div + shift))


def _avgpool_sum(t: np.ndarray) -> np.ndarray:
    """Sum over 2×2 windows (division folded into the requant shift)."""
    return (t[:, :, 0::2, 0::2] + t[:, :, 0::2, 1::2]
            + t[:, :, 1::2, 0::2] + t[:, :, 1::2, 1::2])


def _maxpool(t: np.ndarray) -> np.ndarray:
    return np.maximum(np.maximum(t[:, :, 0::2, 0::2], t[:, :, 0::2, 1::2]),
                      np.maximum(t[:, :, 1::2, 0::2], t[:, :, 1::2, 1::2]))


def reference_forward_int8(weights: CifarCNNWeights, image: np.ndarray,
                           shifts: Sequence[int]
                           ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Bit-exact integer forward pass; returns (logits_int8 (1,10),
    per-layer activations)."""
    acts: Dict[str, np.ndarray] = {}
    x = image.astype(np.int64)

    def conv_block(x, w, b, shift, pad, pool):
        acc = (conv2d_reference(x.astype(np.int8), w, pad=pad)
               + b[None, :, None, None])
        acc = np.maximum(acc, 0)
        if pool == "avg":
            return _requant(_avgpool_sum(acc), 2, shift).astype(np.int64)
        if pool == "max":
            return _requant(_maxpool(acc), 0, shift).astype(np.int64)
        return _requant(acc, 0, shift).astype(np.int64)

    x = conv_block(x, weights.conv1_w, weights.conv1_b.astype(np.int64),
                   shifts[0], 2, "max");  acts["c1"] = x.astype(np.int8)
    x = conv_block(x, weights.conv2_w, weights.conv2_b.astype(np.int64),
                   shifts[1], 1, "avg");  acts["c2"] = x.astype(np.int8)
    x = conv_block(x, weights.conv3_w, weights.conv3_b.astype(np.int64),
                   shifts[2], 1, "max");  acts["c3"] = x.astype(np.int8)

    v = x.reshape(1, -1)                      # (1, 1024), NCHW order
    acc = v @ weights.fc4_w.astype(np.int64) + weights.fc4_b.astype(np.int64)
    acc = np.maximum(acc, 0)
    v = _requant(acc, 0, shifts[3]).astype(np.int64)
    acts["f4"] = v.astype(np.int8)

    acc = v @ weights.fc5_w.astype(np.int64) + weights.fc5_b.astype(np.int64)
    logits = _requant(acc, 0, shifts[4]);  acts["f5"] = logits
    return logits, acts


# ---------------------------------------------------------------------------
# Float reference (stands in for a framework-trained model)
# ---------------------------------------------------------------------------

def reference_forward_float(weights: CifarCNNWeights, image: np.ndarray
                            ) -> np.ndarray:
    """Float32 JAX forward over the same (integer-valued) weights — the
    classification reference; imported lazily so core/ stays JAX-free."""
    import jax.numpy as jnp
    from jax import lax

    x = jnp.asarray(image, jnp.float32)

    def conv(x, w, b, pad, pool):
        y = lax.conv_general_dilated(
            x, jnp.asarray(w, jnp.float32), (1, 1), ((pad, pad), (pad, pad)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = jnp.maximum(y + jnp.asarray(b, jnp.float32)[None, :, None, None],
                        0)
        if pool == "avg":
            y = (y[:, :, 0::2, 0::2] + y[:, :, 0::2, 1::2]
                 + y[:, :, 1::2, 0::2] + y[:, :, 1::2, 1::2]) / 4.0
        elif pool == "max":
            y = jnp.maximum(
                jnp.maximum(y[:, :, 0::2, 0::2], y[:, :, 0::2, 1::2]),
                jnp.maximum(y[:, :, 1::2, 0::2], y[:, :, 1::2, 1::2]))
        return y

    x = conv(x, weights.conv1_w, weights.conv1_b, 2, "max")
    x = conv(x, weights.conv2_w, weights.conv2_b, 1, "avg")
    x = conv(x, weights.conv3_w, weights.conv3_b, 1, "max")
    v = x.reshape(1, -1)
    v = jnp.maximum(v @ jnp.asarray(weights.fc4_w, jnp.float32)
                    + jnp.asarray(weights.fc4_b, jnp.float32), 0)
    logits = (v @ jnp.asarray(weights.fc5_w, jnp.float32)
              + jnp.asarray(weights.fc5_b, jnp.float32))
    return np.asarray(logits)


def synthetic_cifar_image(seed: int = 0) -> np.ndarray:
    """A deterministic 3×32×32 int8 test image (centred dynamic range)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(-64, 64, (1, 3, 32, 32), dtype=np.int64)
    return img.astype(np.int8)


def calibrate_shifts(weights: CifarCNNWeights,
                     images: Sequence[np.ndarray],
                     margin: int = 1) -> List[int]:
    """Static per-layer requant shifts over a calibration set (§4.2)."""
    from repro.core.network_compiler import calibrate_network_shifts
    return calibrate_network_shifts(cifar_cnn_specs(weights), images,
                                    margin=margin)
