"""Deterministic synthetic data pipeline.

Sequences are generated from a seeded per-(step, sequence) hash so any
shard of the global batch is reproducible independently — exactly what a
restart-after-failure needs: the pipeline is stateless, resuming at step N
regenerates the same batches a failed run saw (tested in
tests/test_fault_tolerance.py).

The token stream is a order-2 Markov chain over the vocab (so models can
actually learn structure in the end-to-end example), with labels = next
token.  ``make_global_batch`` builds a sharded ``jax.Array`` directly from
per-shard callbacks — no host gathers the full global batch (the pattern
that scales to 1000+ hosts).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_prefix: int = 0       # VLM/audio: embeddings prefix length
    d_model: int = 0               # for prefix embeddings
    encoder_seq: int = 0           # whisper frames


def _seq_rng(cfg: DataConfig, step: int, index: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, index]))


def synth_sequence(cfg: DataConfig, step: int, index: int) -> np.ndarray:
    """Order-2 Markov chain tokens (seq_len + 1,)."""
    rng = _seq_rng(cfg, step, index)
    v = cfg.vocab
    out = np.empty(cfg.seq_len + 1, np.int32)
    out[0] = rng.integers(v)
    out[1] = rng.integers(v)
    # two cheap hash-mixed transitions make the stream learnable
    a = int(rng.integers(1, v))
    b = int(rng.integers(1, v))
    noise = rng.random(cfg.seq_len + 1)
    for t in range(2, cfg.seq_len + 1):
        if noise[t] < 0.1:
            out[t] = rng.integers(v)
        else:
            out[t] = (a * out[t - 1] + b * out[t - 2] + 7) % v
    return out


def host_batch(cfg: DataConfig, step: int, lo: int, hi: int
               ) -> Dict[str, np.ndarray]:
    """Sequences [lo, hi) of the global batch for this step."""
    seqs = np.stack([synth_sequence(cfg, step, i) for i in range(lo, hi)])
    batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
    if cfg.frontend_prefix and cfg.d_model:
        rng = _seq_rng(cfg, step, -1)
        batch["prefix_embed"] = rng.normal(
            0, 0.02, (hi - lo, cfg.frontend_prefix, cfg.d_model)
        ).astype(np.float32)
    if cfg.encoder_seq and cfg.d_model:
        rng = _seq_rng(cfg, step, -2)
        batch["frames"] = rng.normal(
            0, 0.02, (hi - lo, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32)
    return batch


def make_global_batch(cfg: DataConfig, step: int, mesh: Mesh
                      ) -> Dict[str, jax.Array]:
    """Build the sharded global batch via per-shard callbacks."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = P(batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None))

    def build(name: str, shape, dtype):
        sharding = NamedSharding(mesh, spec)

        def cb(index) -> np.ndarray:
            lo = index[0].start or 0
            hi = index[0].stop or cfg.global_batch
            data = host_batch(cfg, step, lo, hi)[name]
            rest = tuple(sl for sl in index[1:])
            return data[(slice(None),) + rest].astype(dtype)

        return jax.make_array_from_callback(shape, sharding, cb)

    b, s = cfg.global_batch, cfg.seq_len
    out = {"tokens": build("tokens", (b, s), np.int32),
           "labels": build("labels", (b, s), np.int32)}
    if cfg.frontend_prefix and cfg.d_model:
        out["prefix_embed"] = build(
            "prefix_embed", (b, cfg.frontend_prefix, cfg.d_model), np.float32)
    if cfg.encoder_seq and cfg.d_model:
        out["frames"] = build(
            "frames", (b, cfg.encoder_seq, cfg.d_model), np.float32)
    return out
