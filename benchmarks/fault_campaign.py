"""Seeded fault-injection campaign over the guarded serving stack
(DESIGN.md §Hardening, EXPERIMENTS.md §Faults).

For every fault class of :data:`repro.harden.FAULT_CLASSES` × workload
(lenet5, resnet8), inject N seeded faults and classify each serve:

* **recovered** — a guard detected the fault and the restored retry
  returned the bit-exact golden output;
* **masked**    — no guard fired and the output is still golden (the
  upset hit dead state — overwritten before use or never read);
* **sdc**       — silent data corruption: wrong output, nothing fired.
  The headline claim is that this row is **zero** with guards on;
* **unrecovered** — guards detected but could not recover (output
  withheld: the caller gets ``None``, never wrong data).

A small guards-off arm measures the baseline the guards are judged
against (there, "detected" means the backend itself crashed loudly, and
geometry bombs past the static footprint ceiling are scored ``hang``
without being executed).  The overhead rows time plain vs guarded
*batched* serving — the §Hardening budget is <10%.

``FAULT_CAMPAIGN_N`` (default 200) sets N per class per workload; the CI
smoke step runs a tiny N so the campaign logic stays exercised on every
push while the real artifact is produced by the full run.  Every row
name starts with ``faults/`` (``benchmarks.run --only faults/``) and the
collected dict is written to ``BENCH_faults.json``.
"""

from __future__ import annotations

import os
import time
from collections import Counter
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.harden import FAULT_CLASSES, FaultInjector, GuardPolicy
from repro.harden import guards as G
from repro.harden.faults import estimate_footprint

#: injections per fault class per workload (guards-on arm)
N_PER_CLASS = int(os.environ.get("FAULT_CAMPAIGN_N", "200"))
#: the guards-off arm only needs enough samples to show the contrast
N_OFF = max(1, min(N_PER_CLASS, 25))

SEED = 2026


def _build_lenet():
    from repro.core.network_compiler import compile_network
    from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                    synthetic_digit)
    net = compile_network(lenet5_specs(lenet5_random_weights(0)),
                          synthetic_digit(0))
    # oracle shadow for the dual-execution runs: backend diversity on the
    # small workload (the large one uses the fast shadow for wall-clock)
    return net, synthetic_digit(1), "oracle"


def _build_resnet8():
    from repro.models.resnet8 import compile_resnet8, synthetic_image
    net, _graph = compile_resnet8()
    return net, synthetic_image(1), "fast"


WORKLOADS: Tuple[Tuple[str, Callable], ...] = (
    ("lenet5", _build_lenet),
    ("resnet8", _build_resnet8),
)


def _classify(out, golden, report) -> str:
    if out is None:
        return "unrecovered"
    if not np.array_equal(out, golden):
        return "sdc"
    return "recovered" if report.detections else "masked"


def _guarded_arm(net, image, dual_backend: str, inj: FaultInjector,
                 n: int) -> Dict[str, Dict[str, int]]:
    golden_out = net.serve_one(image)
    golden = G.golden_of(net)
    results: Dict[str, Dict[str, int]] = {}
    for cls in FAULT_CLASSES:
        tally: Counter = Counter()
        policy = GuardPolicy(dual_execute=(cls == "sram"),
                             dual_backend=dual_backend)
        for _ in range(n):
            spec, hook = inj.inject(net, cls)
            if cls == "insn-bits":
                # fetch the corrupted stream like the device would; an
                # undecodable word leaves the stale decode in place — the
                # segment CRC detects the corruption either way
                try:
                    inj.materialize(net, spec)
                except ValueError:
                    pass
            out, rep = net.serve_one(image, guard=policy, fault_hook=hook)
            tally[_classify(out, golden_out, rep)] += 1
            G.restore_network(net, golden)   # clean slate between trials
        results[cls] = dict(tally)
    return results


def _unguarded_arm(net, image, inj: FaultInjector,
                   n: int) -> Dict[str, Dict[str, int]]:
    golden_out = net.serve_one(image)
    golden = G.golden_of(net)
    results: Dict[str, Dict[str, int]] = {}
    for cls in FAULT_CLASSES:
        tally: Counter = Counter()
        for _ in range(n):
            spec, hook = inj.inject(net, cls)
            decode_failed = False
            if cls == "insn-bits":
                try:
                    inj.materialize(net, spec)
                except ValueError:
                    decode_failed = True     # device faults on the fetch
            bomb = any(
                estimate_footprint(l.program.instructions)
                > G.MAX_INSN_FOOTPRINT for l in net.layers)
            if decode_failed:
                tally["detected"] += 1
            elif bomb:
                # a corrupted loop field turned an instruction into a
                # resource bomb — executing it would burn minutes/GiB, so
                # score it as the hang it models and move on
                tally["hang"] += 1
            else:
                try:
                    out = net.serve_one(image, fault_hook=hook)
                except Exception:            # noqa: BLE001 — any crash
                    tally["detected"] += 1
                else:
                    tally["masked" if np.array_equal(out, golden_out)
                          else "sdc"] += 1
            G.restore_network(net, golden)
        results[cls] = dict(tally)
    return results


def _overhead(net, image, reps: int = 7) -> Dict[str, float]:
    imgs = [image] * 8

    def best(f) -> float:
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            f()
            times.append(time.perf_counter() - t0)
        return min(times)

    net.serve(imgs)                              # warm plan + caches
    net.serve(imgs, guard=GuardPolicy())         # warm golden + validator
    plain = best(lambda: net.serve(imgs))
    guarded = best(lambda: net.serve(imgs, guard=GuardPolicy()))
    return {"batched8_plain_ms": round(plain * 1e3, 3),
            "batched8_guarded_ms": round(guarded * 1e3, 3),
            "overhead_pct": round(100 * (guarded / plain - 1), 2)}


def collect() -> Dict:
    data: Dict = {"n_per_class": N_PER_CLASS, "n_unguarded": N_OFF,
                  "seed": SEED, "workloads": {}}
    for name, build in WORKLOADS:
        net, image, dual_backend = build()
        inj = FaultInjector(seed=SEED)
        guarded = _guarded_arm(net, image, dual_backend, inj, N_PER_CLASS)
        unguarded = _unguarded_arm(net, image, inj, N_OFF)
        data["workloads"][name] = {
            "guarded": guarded,
            "unguarded": unguarded,
            "sdc_guarded": sum(t.get("sdc", 0) for t in guarded.values()),
            "sdc_unguarded": sum(t.get("sdc", 0)
                                 for t in unguarded.values()),
            "timing": _overhead(net, image),
        }
    return data


def all_tables(data: Dict = None) -> List[Dict]:
    data = data or collect()
    rows: List[Dict] = [
        {"name": "faults/n_per_class", "value": data["n_per_class"],
         "paper": None}]
    for wl, d in data["workloads"].items():
        for cls in FAULT_CLASSES:
            tally = d["guarded"].get(cls, {})
            for outcome in ("recovered", "masked", "unrecovered", "sdc"):
                if outcome in tally:
                    rows.append({"name": f"faults/{wl}/{cls}/{outcome}",
                                 "value": tally[outcome], "paper": None})
        # the headline row: the guarded stack's total silent corruptions
        sdc = d["sdc_guarded"]
        # str so the EXACT_ROWS bit-for-bit comparison in benchmarks.run
        # can enforce the zero-SDC claim (a nonzero count fails the run)
        rows.append({"name": f"faults/{wl}/sdc_total", "value": str(sdc),
                     "paper": "0"})
        rows.append({"name": f"faults/{wl}/sdc_unguarded_baseline",
                     "value": d["sdc_unguarded"], "paper": None,
                     "note": f"of {data['n_unguarded'] * len(FAULT_CLASSES)}"
                             f" unguarded injections"})
        t = d["timing"]
        rows.append({"name": f"faults/{wl}/serve_batched8_plain_ms",
                     "value": t["batched8_plain_ms"], "paper": None})
        rows.append({"name": f"faults/{wl}/serve_batched8_guarded_ms",
                     "value": t["batched8_guarded_ms"], "paper": None})
        rows.append({"name": f"faults/{wl}/guard_overhead_pct",
                     "value": t["overhead_pct"], "paper": None,
                     "note": "budget <10%"})
    return rows
