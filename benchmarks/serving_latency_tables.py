"""Serving-latency benchmarks: throughput–latency curves vs offered load
(EXPERIMENTS.md §Serving-latency, DESIGN.md §Serving).

For lenet5 and resnet8 on the batched backend:

1. calibrate a deterministic :class:`ServiceModel` from real timed
   serves (the one wall-clock step);
2. sweep ≥3 offered loads — 0.5×, 0.8× and 1.2× of the modeled
   two-worker capacity — through the virtual-clock discrete-event
   simulation of the engine's own max-batch/max-wait policy, emitting
   p50/p99 latency, throughput, batch occupancy, SLO violations and
   backpressure rejections per load point;
3. ``servelat/<net>/bit_identity`` (EXACT): the *threaded* engine's
   outputs for a seeded request set must equal a direct
   ``NetworkProgram.serve`` of the same images bit-for-bit;
4. ``servelat/<net>/deterministic_replay`` (EXACT): two same-seed
   virtual-clock runs must produce identical request traces and latency
   histograms.

``SERVING_CAMPAIGN_N`` scales the per-load request count (default 200;
CI smoke runs a small N).  Timing-derived rows are reported, not gated —
container throughput varies run to run; the EXACT rows gate the
correctness and determinism contracts, which do not.
"""

from __future__ import annotations

import os
from typing import Dict, List

import numpy as np

from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)
from repro.serving.vta import (BatchPolicy, PoissonSource, VTAServingEngine,
                               calibrate_service_model, request_images,
                               serve_all, simulate)

WORKERS = 2
MAX_BATCH = 8
LOAD_FACTORS = (0.5, 0.8, 1.2)
BIT_IDENTITY_N = 12


def _lenet5():
    return compile_network(lenet5_specs(lenet5_random_weights(0)),
                           synthetic_digit(0))


def _resnet8():
    from repro.models.resnet8 import compile_resnet8
    net, _ = compile_resnet8()
    return net


def _campaign_n() -> int:
    return int(os.environ.get("SERVING_CAMPAIGN_N", "200"))


def _curve(net, model, policy, slo_s, n) -> List[Dict]:
    capacity_rps = WORKERS * MAX_BATCH / model.service_s(MAX_BATCH)
    points = []
    for i, factor in enumerate(LOAD_FACTORS):
        rate = factor * capacity_rps
        result = simulate(PoissonSource(rate, n, seed=100 + i), policy,
                          model, workers=WORKERS, slo_s=slo_s)
        summary = result.metrics.summary()
        audit = result.metrics.audit()
        if audit:
            raise AssertionError(f"SLO accounting errors at load "
                                 f"{factor}: {audit}")
        points.append({
            "load_factor": factor,
            "offered_rps": round(float(rate), 2),
            "throughput_rps": round(float(summary["throughput_rps"]), 2),
            "p50_ms": round(float(summary["p50_ms"]), 4),
            "p99_ms": round(float(summary["p99_ms"]), 4),
            "mean_batch_occupancy": round(
                float(summary["mean_batch_occupancy"]), 3),
            "slo_violations": int(summary["slo_violations"]),
            "rejected": int(summary["rejected"]),
            "completed": int(summary["completed"]),
        })
    return points


def _bit_identity(net, tag: str) -> str:
    """Threaded engine vs direct serve on the same seeded images."""
    images = request_images(net, BIT_IDENTITY_N, seed=11)
    policy = BatchPolicy(max_batch=4, max_wait_s=0.002, max_depth=64)
    engine = VTAServingEngine(net, policy=policy,
                              backends=("batched", "batched")).start()
    try:
        outs, _ = serve_all(engine, images)
    finally:
        engine.shutdown()
    audit = engine.metrics.audit()
    if audit:
        raise AssertionError(f"{tag}: engine accounting errors: {audit}")
    direct, _ = net.serve(images)
    return "PASS" if np.array_equal(outs, direct) else "FAIL"


def _deterministic_replay(net, model, policy, slo_s, n) -> str:
    runs = []
    for _ in range(2):
        result = simulate(PoissonSource(0.8 * WORKERS * MAX_BATCH
                                        / model.service_s(MAX_BATCH),
                                        n, seed=42),
                          policy, model, workers=WORKERS, slo_s=slo_s)
        runs.append((result.trace(),
                     result.metrics.latency_histogram(),
                     result.metrics.summary()))
    same = (runs[0][0] == runs[1][0] and runs[0][1] == runs[1][1]
            and runs[0][2] == runs[1][2])
    return "PASS" if same else "FAIL"


def collect() -> Dict:
    n = _campaign_n()
    replay_n = min(n, 100)
    data: Dict = {"campaign_n": n, "workers": WORKERS,
                  "max_batch": MAX_BATCH, "load_factors": LOAD_FACTORS,
                  "backend": "batched", "nets": {}}
    for tag, make_net in (("lenet5", _lenet5), ("resnet8", _resnet8)):
        net = make_net()
        model = calibrate_service_model(net, batch=MAX_BATCH)
        policy = BatchPolicy(max_batch=MAX_BATCH,
                             max_wait_s=model.service_s(MAX_BATCH),
                             max_depth=8 * MAX_BATCH)
        slo_s = 10 * model.service_s(MAX_BATCH)
        data["nets"][tag] = {
            "service_model": {"base_ms": round(model.base_s * 1e3, 4),
                              "per_image_ms": round(
                                  model.per_image_s * 1e3, 4)},
            "slo_ms": round(slo_s * 1e3, 4),
            "curve": _curve(net, model, policy, slo_s, n),
            "bit_identity": _bit_identity(net, tag),
            "deterministic_replay": _deterministic_replay(
                net, model, policy, slo_s, replay_n),
        }
    return data


def all_tables(data: Dict) -> List[Dict]:
    rows: List[Dict] = []
    for tag, entry in data["nets"].items():
        for point in entry["curve"]:
            rho = point["load_factor"]
            rows.append({"name": f"servelat/{tag}/p50_ms@rho{rho}",
                         "value": point["p50_ms"], "paper": None,
                         "note": f"offered={point['offered_rps']}rps"})
            rows.append({"name": f"servelat/{tag}/p99_ms@rho{rho}",
                         "value": point["p99_ms"], "paper": None,
                         "note": f"slo_viol={point['slo_violations']} "
                                 f"rejected={point['rejected']}"})
            rows.append({"name": f"servelat/{tag}/throughput_rps@rho{rho}",
                         "value": point["throughput_rps"], "paper": None,
                         "note": f"occupancy="
                                 f"{point['mean_batch_occupancy']}"})
        rows.append({"name": f"servelat/{tag}/bit_identity",
                     "value": entry["bit_identity"], "paper": "PASS",
                     "note": "engine == direct serve, bit-exact"})
        rows.append({"name": f"servelat/{tag}/deterministic_replay",
                     "value": entry["deterministic_replay"],
                     "paper": "PASS",
                     "note": "same seed => identical trace+histogram"})
    return rows
