"""resnet_tiny benchmarks — the graph-compiled branching workload
(DESIGN.md §Graph).

No paper column: the paper's compiler cannot express branching CNNs at
all, so these rows document what the graph subsystem opens — per-layer
chunk counts and GeMM loops, the on-VTA residual-add instruction counts,
and serving throughput (per-image fast loop vs the batched runtime) next
to the LeNet/CIFAR numbers (EXPERIMENTS.md §Serving).

``artifact()`` returns the same measurements as a JSON-ready dict;
``benchmarks.run`` writes it to ``BENCH_resnet_tiny.json`` so the perf
trajectory has machine-readable data points.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import isa
from repro.core.cycle_model import FPGA_CLOCK_HZ


def _network():
    from repro.models.resnet_tiny import compile_resnet_tiny
    return compile_resnet_tiny()


def _alu_add_insns(prog) -> int:
    """Vector-vector (non-imm) ALU ADD instructions — the on-VTA residual
    adds and GAP tree rounds.  Shared with ``resnet8_tables``."""
    return sum(1 for i in prog.instructions
               if isinstance(i, isa.AluInsn)
               and i.alu_opcode == isa.AluOp.ADD and not i.use_imm)


def _serve_rates(net, image_fn, *, requests: int = 8, batch: int = 8):
    """(fast-loop img/s, batched img/s) for a compiled network;
    ``image_fn(seed)`` supplies request images.  Shared with
    ``resnet8_tables``."""
    imgs = [image_fn(200 + r) for r in range(requests)]
    net.serve_one(imgs[0])                      # warm the plan caches
    t0 = time.perf_counter()
    for img in imgs:
        net.serve_one(img, backend="fast")
    loop_s = time.perf_counter() - t0
    net.serve(imgs[:batch])                     # warm batched staging
    t0 = time.perf_counter()
    net.serve(imgs[:batch])
    batched_s = time.perf_counter() - t0
    return requests / loop_s, batch / batched_s


def collect() -> Dict:
    """One measurement pass → the shared dict behind the CSV rows and the
    ``BENCH_resnet_tiny.json`` artifact."""
    t0 = time.perf_counter()
    net, _graph = _network()
    compile_s = time.perf_counter() - t0
    cr = net.cycle_report()
    from repro.models.resnet_tiny import synthetic_image
    loop_rate, batched_rate = _serve_rates(net, synthetic_image)
    return {
        "workload": "resnet_tiny",
        "compile_wall_s": round(compile_s, 3),
        "layers": [
            {"name": l.spec.name, "chunks": l.n_chunks,
             "gemm_loops": l.program.gemm_loops(),
             "residual": bool(l.spec.residual_add),
             "alu_add_insns": _alu_add_insns(l.program)}
            for l in net.layers],
        "residual_joins": sum(1 for l in net.layers if l.spec.residual_add),
        "gemm_loops_total": net.gemm_loops(),
        "compute_cycles": cr.total_compute_cycles,
        "compute_load_cycles": cr.compute_load_cycles,
        "exec_us_at_650mhz": round(cr.execution_time_s(
            FPGA_CLOCK_HZ, include_loads=True) * 1e6, 2),
        "serve_img_per_s_fast_loop": round(loop_rate, 1),
        "serve_img_per_s_batched@8": round(batched_rate, 1),
    }


def all_tables(data: Dict = None) -> List[Dict]:
    data = data or collect()
    rows: List[Dict] = []
    for layer in data["layers"]:
        rows.append({"name": f"graph/chunks/{layer['name']}",
                     "value": layer["chunks"], "paper": None})
        rows.append({"name": f"graph/gemm_loops/{layer['name']}",
                     "value": layer["gemm_loops"], "paper": None})
        if layer["residual"]:
            rows.append({"name": f"graph/alu_add_insns/{layer['name']}",
                         "value": layer["alu_add_insns"], "paper": None})
    rows.append({"name": "graph/residual_joins",
                 "value": data["residual_joins"], "paper": None})
    rows.append({"name": "graph/gemm_loops/total",
                 "value": data["gemm_loops_total"], "paper": None})
    rows.append({"name": "graph/cycles/total_compute",
                 "value": data["compute_cycles"], "paper": None})
    rows.append({"name": "graph/cycles/compute_loads",
                 "value": data["compute_load_cycles"], "paper": None})
    rows.append({"name": "graph/exec_us@650MHz",
                 "value": data["exec_us_at_650mhz"], "paper": None})
    rows.append({"name": "graph/compile_wall_s",
                 "value": data["compile_wall_s"], "paper": None})
    rows.append({"name": "serve/resnet_tiny/fast_loop_img_per_s",
                 "value": data["serve_img_per_s_fast_loop"], "paper": None})
    rows.append({"name": "serve/resnet_tiny/batched@8_img_per_s",
                 "value": data["serve_img_per_s_batched@8"], "paper": None})
    return rows
