"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (a
correctness vehicle, not a speed one), so wall-times here measure (a) the
XLA-CPU reference path of the fused W8A8 GEMM semantics and (b) the
functional-simulator instruction throughput.  On a real TPU the same
harness times the Pallas kernels.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, repeats=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gemm_bench() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    for m, k, n in [(256, 256, 256), (512, 512, 512), (1024, 1024, 1024)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        f = jax.jit(lambda a, b: ref.vta_gemm_ref(a, b, relu=True, shift=4))
        dt = _time(f, a, b)
        flops = 2 * m * k * n
        rows.append({"name": f"w8a8_gemm_xla/{m}x{k}x{n}_us",
                     "value": round(dt * 1e6, 1),
                     "derived": f"{flops / dt / 1e9:.1f} GOP/s"})
    return rows


def attention_bench() -> List[Dict]:
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    dt = _time(f, q, k, v)
    return [{"name": "attention_ref_xla/b1h8s512d64_us",
             "value": round(dt * 1e6, 1), "derived": ""}]


def all_tables() -> List[Dict]:
    return gemm_bench() + attention_bench()
