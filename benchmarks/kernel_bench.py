"""Kernel micro-benchmarks (``kernel/*`` rows).

Every row carries the ``kernel/`` prefix ``benchmarks/run.py`` claims for
this section, so ``--only kernel/`` emits rows on every host (the PR-5
fail-loud rule: a silent empty table is indistinguishable from a broken
one).  The fused-GEMM rows time *both* legs: the XLA reference
(``kernel/w8a8_gemm_xla/…``) and the real Pallas kernel — which off-TPU
runs in interpret mode, reported in the row name
(``kernel/w8a8_gemm_pallas_interpret/…``) so a CPU container's
correctness-vehicle numbers can never be mistaken for TPU wall times.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _pallas_mode() -> str:
    """The Pallas execution mode, encoded into row names: real kernels on
    TPU, interpret-mode emulation elsewhere (a correctness vehicle whose
    wall times must stay visibly labelled as such)."""
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def _time(fn, *args, repeats=5) -> float:
    # Warm up (trigger compilation); block_until_ready traverses pytrees,
    # so it blocks on tuple returns and bare arrays alike.
    warmup = fn(*args)
    jax.block_until_ready(warmup)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def gemm_bench() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    mode = _pallas_mode()
    for m, k, n in [(256, 256, 256), (512, 512, 512), (1024, 1024, 1024)]:
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        f = jax.jit(lambda a, b: ref.vta_gemm_ref(a, b, relu=True, shift=4))
        dt = _time(f, a, b)
        flops = 2 * m * k * n
        rows.append({"name": f"kernel/w8a8_gemm_xla/{m}x{k}x{n}_us",
                     "value": round(dt * 1e6, 1),
                     "derived": f"{flops / dt / 1e9:.1f} GOP/s"})
        dt = _time(lambda a, b: ops.vta_matmul(a, b, relu=True, shift=4,
                                               backend="pallas"), a, b)
        rows.append({"name": f"kernel/w8a8_gemm_{mode}/{m}x{k}x{n}_us",
                     "value": round(dt * 1e6, 1),
                     "derived": f"{flops / dt / 1e9:.1f} GOP/s"})
    return rows


def attention_bench() -> List[Dict]:
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    dt = _time(f, q, k, v)
    rows = [{"name": "kernel/attention_ref_xla/b1h8s512d64_us",
             "value": round(dt * 1e6, 1), "derived": ""}]
    dt = _time(lambda q, k, v: ops.attention_pallas(q, k, v, causal=True),
               q, k, v)
    rows.append({"name": f"kernel/attention_{_pallas_mode()}/"
                         f"b1h8s512d64_us",
                 "value": round(dt * 1e6, 1), "derived": ""})
    return rows


def simulator_bench(repeats: int = 3) -> List[Dict]:
    """Functional-simulator throughput, oracle vs the vectorised fast path.

    Reported per backend: end-to-end LeNet-5 simulation wall time,
    instructions/s and GeMM-loops/s — the perf-trajectory rows for the
    fast-path speedup (target ≥10×).
    """
    from repro.core.network_compiler import compile_network
    from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                    synthetic_digit)

    net = compile_network(lenet5_specs(lenet5_random_weights(0)),
                          synthetic_digit(0))
    n_insn = sum(len(l.program.instructions) for l in net.layers)
    loops = net.gemm_loops()
    rows: List[Dict] = []
    wall: Dict[str, float] = {}
    for backend in ("oracle", "fast"):
        # Warm up: compiles + caches the instruction plans on the fast path.
        net.run_functional(check_chaining=False, backend=backend)
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            net.run_functional(check_chaining=False, backend=backend)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        wall[backend] = dt
        rows.append({"name": f"kernel/sim/{backend}/lenet5_wall_ms",
                     "value": round(dt * 1e3, 2), "derived": ""})
        rows.append({"name": f"kernel/sim/{backend}/insn_per_s",
                     "value": int(n_insn / dt), "derived": ""})
        rows.append({"name": f"kernel/sim/{backend}/gemm_loops_per_s",
                     "value": int(loops / dt), "derived": ""})
    rows.append({"name": "kernel/sim/fast_speedup_x",
                 "value": round(wall["oracle"] / wall["fast"], 1),
                 "derived": "target >=10x"})
    return rows


def all_tables() -> List[Dict]:
    return gemm_bench() + attention_bench() + simulator_bench()
