"""Roofline analysis (deliverable (g), EXPERIMENTS.md §Roofline).

Reads the dry-run JSONs (experiments/dryrun/*.json) and derives, per
(arch × shape × mesh):

    compute term    = FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HBM_bytes_per_device / HBM_bw           [s]
    collective term = wire_bytes_per_device / ICI_link_bw     [s]

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(conservative single-link bottleneck; inter-pod DCI counted at 25 GB/s).

Also reports MODEL_FLOPS (6·N·D train / 2·N·D prefill / 2·N_active·B
decode), the useful-compute ratio MODEL/HLO, the dominant term, and the
roofline fraction  max-term / sum-of-terms-bound:

    step_time_lower_bound ≈ max(terms)      (perfect overlap)
    roofline_fraction     = compute_term / max(terms)

— i.e. how close the cell is to being compute-bound at peak; 1.0 means the
MXU is the binding resource (the best a lowering can do).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (conservative)
DCI_BW = 25e9                # inter-pod

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def _params(arch: str) -> Dict[str, float]:
    if arch not in _PARAM_CACHE:
        from repro.configs import get_config
        from repro.models.params import param_count
        from repro.models.transformer import model_defs
        cfg = get_config(arch)
        total = param_count(model_defs(cfg))
        active = cfg.active_param_count_estimate()
        # scale estimate to the exact total (estimates share structure)
        est_total = cfg.param_count_estimate()
        if est_total > 0:
            active = active / est_total * total
        _PARAM_CACHE[arch] = {"total": float(total), "active": float(active)}
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape: str) -> float:
    """Global useful FLOPs of one step: 6·N·D train, 2·N·D prefill,
    2·N_active·B decode (one token per sequence)."""
    from repro.configs import SHAPES
    p = _params(arch)
    sh = SHAPES[shape]
    tokens = sh.global_batch * sh.seq_len
    if sh.kind == "train":
        return 6.0 * p["active"] * tokens
    if sh.kind == "prefill":
        return 2.0 * p["active"] * tokens
    return 2.0 * p["active"] * sh.global_batch     # decode: 1 new token/seq


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    args_gb_per_device: float
    compile_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent at peak MXU — 1.0 = compute-
        bound (cannot do better by changing the distribution/layout)."""
        return self.compute_s / self.bound_s if self.bound_s else 0.0

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/masking waste."""
        return (self.model_flops / self.hlo_flops_global
                if self.hlo_flops_global else 0.0)


def load_cell(path: pathlib.Path) -> Optional[Cell]:
    d = json.loads(path.read_text())
    cost = d.get("cost")
    if not cost:
        return None
    wire = cost["collective_wire_per_device"]
    inter = cost.get("collective_wire_interpod", 0.0)
    coll_s = (wire - inter) / ICI_BW + inter / DCI_BW
    # TPU-fusion bytes model when available (raw CPU-HLO bytes count every
    # unfused elementwise intermediate a TPU would keep in VMEM)
    hbm = cost.get("bytes_fused_per_device", cost["bytes_per_device"])
    return Cell(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
        devices=d["devices"],
        compute_s=cost["flops_per_device"] / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=coll_s,
        model_flops=model_flops(d["arch"], d["shape"]),
        hlo_flops_global=cost["flops_per_device"] * d["devices"],
        args_gb_per_device=(d.get("arg_bytes_per_device") or 0) / 1e9,
        compile_s=d.get("compile_s", 0.0),
    )


def load_all(dirpath="experiments/dryrun") -> List[Cell]:
    cells = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        c = load_cell(p)
        if c:
            cells.append(c)
    return cells


ADVICE = {
    "compute": "compute-bound: already at the MXU roofline — gains only "
               "from cutting redundant FLOPs (remat policy, causal skip)",
    "memory": "HBM-bound: raise arithmetic intensity (larger tiles/fusion, "
              "smaller dtype, fewer materialised intermediates)",
    "collective": "collective-bound: change sharding to cut gathered bytes "
                  "(SP residuals, expert-parallel a2a, int8 pod reduce)",
}


def table(cells: List[Cell], mesh: str = "16x16") -> str:
    rows = [c for c in cells if c.mesh == mesh]
    rows.sort(key=lambda c: (c.arch, c.shape))
    out = ["| arch | shape | compute s | memory s | collective s | bound | "
           "roofline | MODEL/HLO | args GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for c in rows:
        out.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e}"
            f" | {c.collective_s:.3e} | {c.dominant} |"
            f" {c.roofline_fraction:.2f} | {c.useful_ratio:.2f} |"
            f" {c.args_gb_per_device:.1f} |")
    return "\n".join(out)


def main():
    dirpath = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    cells = load_all(dirpath)
    if not cells:
        print("no dry-run results found — run repro.launch.dryrun first")
        return
    for mesh in ("16x16", "2x16x16"):
        sub = [c for c in cells if c.mesh == mesh]
        if not sub:
            continue
        print(f"\n== mesh {mesh} ({len(sub)} cells) ==")
        print(table(cells, mesh))
    print("\nworst roofline fractions (single-pod):")
    sp = sorted((c for c in cells if c.mesh == "16x16"),
                key=lambda c: c.roofline_fraction)
    for c in sp[:5]:
        print(f"  {c.arch} × {c.shape}: {c.roofline_fraction:.3f} "
              f"({c.dominant}-bound) — {ADVICE[c.dominant]}")


if __name__ == "__main__":
    main()
