"""Batched-serving throughput benchmarks (DESIGN.md §Batching).

Images/sec by serving mode — the oracle-interpreter loop, the looped fast
backend (one VTA chain per request, plans cached), and the batched
runtime (one compiled plan per layer over the whole request batch) at
several batch sizes.  All three produce bit-identical logits (enforced by
tests/test_batched_serving.py); the table documents what the batch axis
buys (EXPERIMENTS.md §Serving).  The headline row,
``serve/lenet/batched_vs_loop_fast_speedup@32``, targets ≥ 2× (the
ISSUE 3 acceptance criterion; measured 2.5–4.3× in this container).
Timing rows are reported, not CI-gated — container throughput varies
±30% run to run, so gating would flake; the bit-exactness contract is
what the test suites enforce.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)

_ORACLE_IMAGES = 2          # the oracle loop is ~100× slower; sample it


def _lenet():
    return compile_network(lenet5_specs(lenet5_random_weights(0)),
                           synthetic_digit(0))


def _cifar():
    from repro.models.cifar_cnn import (calibrate_shifts,
                                        cifar_cnn_random_weights,
                                        cifar_cnn_specs,
                                        synthetic_cifar_image)
    weights = cifar_cnn_random_weights(0)
    shifts = calibrate_shifts(
        weights, [synthetic_cifar_image(s) for s in range(1, 3)])
    return compile_network(cifar_cnn_specs(weights, shifts),
                           synthetic_cifar_image(0))


def _images(net, n, seed=0):
    rng = np.random.default_rng(seed)
    shape = net.input_tensor.shape
    return [rng.integers(-64, 64, shape).astype(np.int8) for _ in range(n)]


def _time_loop(net, images, backend):
    t0 = time.perf_counter()
    for img in images:
        net.serve_one(img, backend=backend)
    return len(images) / (time.perf_counter() - t0)


def _time_batched(net, images):
    t0 = time.perf_counter()
    net.serve(images)
    return len(images) / (time.perf_counter() - t0)


def _serving_rows(tag: str, net, *, batches=(8, 32), loop_n=32,
                  oracle_n=_ORACLE_IMAGES) -> List[Dict]:
    rows: List[Dict] = []
    images = _images(net, max(max(batches), loop_n), seed=1)
    net.serve(images[:2])                       # warm plans + caches
    net.serve_one(images[0], backend="fast")
    if oracle_n:
        rows.append({"name": f"serve/{tag}/loop_oracle_img_per_s",
                     "value": round(_time_loop(net, images[:oracle_n],
                                               "oracle"), 2),
                     "paper": None})
    loop_fast = _time_loop(net, images[:loop_n], "fast")
    rows.append({"name": f"serve/{tag}/loop_fast_img_per_s",
                 "value": round(loop_fast, 1), "paper": None})
    batched_rate = {}
    for b in batches:
        batched_rate[b] = _time_batched(net, images[:b])
        rows.append({"name": f"serve/{tag}/batched_img_per_s@{b}",
                     "value": round(batched_rate[b], 1), "paper": None})
    top = max(batches)
    rows.append({"name": f"serve/{tag}/batched_vs_loop_fast_speedup@{top}",
                 "value": round(batched_rate[top] / loop_fast, 2),
                 "paper": None,
                 "note": "target >= 2x (ISSUE 3 acceptance)"})
    return rows


def all_tables() -> List[Dict]:
    rows = _serving_rows("lenet", _lenet())
    rows += _serving_rows("cifar", _cifar(), batches=(8,), loop_n=8,
                          oracle_n=0)
    return rows
