"""resnet8 benchmarks — the strided/GAP workload (DESIGN.md
§Strided-lowering).

No paper column: the paper's compiler has neither stride-2 convolutions
nor global average pooling, so these rows document what the strided
lowering opens — per-layer stride/chunk/GeMM-loop schedules, the GAP
tree-reduction instruction counts, and serving throughput (per-image
fast loop vs the batched runtime) next to the resnet_tiny numbers
(EXPERIMENTS.md §Resnet8).

``collect()`` returns the measurements as a JSON-ready dict;
``benchmarks.run`` writes it to ``BENCH_resnet8.json`` so the perf
trajectory has machine-readable data points.  Every row name starts
with ``resnet8/`` so ``benchmarks.run --only resnet8/`` runs exactly
this table (the CI smoke step).
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.resnet_tables import _alu_add_insns, _serve_rates
from repro.core.cycle_model import FPGA_CLOCK_HZ


def _network():
    from repro.models.resnet8 import compile_resnet8
    return compile_resnet8()


def collect() -> Dict:
    """One measurement pass → the shared dict behind the CSV rows and the
    ``BENCH_resnet8.json`` artifact."""
    t0 = time.perf_counter()
    net, _graph = _network()
    compile_s = time.perf_counter() - t0
    cr = net.cycle_report()
    from repro.models.resnet8 import synthetic_image
    loop_rate, batched_rate = _serve_rates(net, synthetic_image)
    head = [l for l in net.layers if l.spec.pool == "gap"][0]
    return {
        "workload": "resnet8",
        "compile_wall_s": round(compile_s, 3),
        "layers": [
            {"name": l.spec.name, "stride": l.spec.stride,
             "chunks": l.n_chunks, "gemm_loops": l.program.gemm_loops(),
             "residual": bool(l.spec.residual_add),
             "alu_add_insns": _alu_add_insns(l.program)}
            for l in net.layers],
        "stride2_convs": sum(1 for l in net.layers if l.spec.stride == 2),
        "residual_joins": sum(1 for l in net.layers if l.spec.residual_add),
        "gap_tree_rounds": _alu_add_insns(head.program),
        "gemm_loops_total": net.gemm_loops(),
        "compute_cycles": cr.total_compute_cycles,
        "compute_load_cycles": cr.compute_load_cycles,
        "exec_us_at_650mhz": round(cr.execution_time_s(
            FPGA_CLOCK_HZ, include_loads=True) * 1e6, 2),
        "serve_img_per_s_fast_loop": round(loop_rate, 1),
        "serve_img_per_s_batched@8": round(batched_rate, 1),
    }


def all_tables(data: Dict = None) -> List[Dict]:
    data = data or collect()
    rows: List[Dict] = []
    for layer in data["layers"]:
        rows.append({"name": f"resnet8/chunks/{layer['name']}",
                     "value": layer["chunks"], "paper": None})
        rows.append({"name": f"resnet8/gemm_loops/{layer['name']}",
                     "value": layer["gemm_loops"], "paper": None})
        if layer["stride"] == 2:
            rows.append({"name": f"resnet8/stride/{layer['name']}",
                         "value": layer["stride"], "paper": None})
    rows.append({"name": "resnet8/stride2_convs",
                 "value": data["stride2_convs"], "paper": None})
    rows.append({"name": "resnet8/residual_joins",
                 "value": data["residual_joins"], "paper": None})
    rows.append({"name": "resnet8/gap_tree_rounds",
                 "value": data["gap_tree_rounds"], "paper": None})
    rows.append({"name": "resnet8/gemm_loops/total",
                 "value": data["gemm_loops_total"], "paper": None})
    rows.append({"name": "resnet8/cycles/total_compute",
                 "value": data["compute_cycles"], "paper": None})
    rows.append({"name": "resnet8/cycles/compute_loads",
                 "value": data["compute_load_cycles"], "paper": None})
    rows.append({"name": "resnet8/exec_us@650MHz",
                 "value": data["exec_us_at_650mhz"], "paper": None})
    rows.append({"name": "resnet8/compile_wall_s",
                 "value": data["compile_wall_s"], "paper": None})
    rows.append({"name": "resnet8/serve/fast_loop_img_per_s",
                 "value": data["serve_img_per_s_fast_loop"], "paper": None})
    rows.append({"name": "resnet8/serve/batched@8_img_per_s",
                 "value": data["serve_img_per_s_batched@8"], "paper": None})
    return rows
