"""Dataset-scale accuracy tables (EXPERIMENTS.md §Accuracy).

The quantization claim the rest of the benchmark suite presupposes:
int8 VTA serving of quantized-from-float LeNet-5 / resnet8 stays within
2 points of float top-1 on a >= 2,000-image held-out digit split.  The
``accuracy/<net>/int8_within_2pct_of_float`` rows are EXACT gates
(``PASS`` must match bit-for-bit in ``benchmarks.run``), as is the
pallas spot-check bit-identity.

``collect()`` drives :func:`repro.quantize.evaluate_net` for both nets —
float front door (seeded JAX training over the procedural digit
dataset) → PTQ (:func:`repro.quantize.quantize_network`) → batched
serving of the test split.  Sizes come from ``ACCURACY_*`` env vars so
the CI smoke step can run a reduced split without forking the code
path; the defaults are the publishable full-scale run (the JSON records
whatever sizes actually ran).  Every row name starts with ``accuracy/``
so ``benchmarks.run --only accuracy/`` runs exactly this table.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

# int8 must stay within this many top-1 points of float (the EXACT gate).
GATE_POINTS = 2.0

NETS = ("lenet5", "resnet8")


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def collect() -> Dict:
    """One evaluation pass per net → the shared dict behind the CSV rows
    and the ``BENCH_accuracy.json`` artifact."""
    from repro.quantize import evaluate_net
    sizes = {
        "train_n": _env_int("ACCURACY_TRAIN_N", 4000),
        "eval_n": _env_int("ACCURACY_EVAL_N", 2000),
        "calib_n": _env_int("ACCURACY_CALIB_N", 64),
        "epochs": _env_int("ACCURACY_EPOCHS", 6),
    }
    nets = []
    for net in NETS:
        t0 = time.perf_counter()
        rec = evaluate_net(net, **sizes)
        rec["wall_s"] = round(time.perf_counter() - t0, 1)
        nets.append(rec)
    return {"gate_points": GATE_POINTS, **sizes, "nets": nets}


def all_tables(data: Dict = None) -> List[Dict]:
    data = data or collect()
    rows: List[Dict] = []
    for rec in data["nets"]:
        net = rec["net"]
        # gate the *published* (2-decimal) delta, not the raw float —
        # (0.9475 - 0.9275) * 100 is 2.0000000000000018, which must
        # read as exactly the 2.00 points the table prints
        delta = round(rec["delta_points"], 2)
        rows.append({"name": f"accuracy/{net}/eval_images",
                     "value": rec["n_eval"], "paper": None})
        rows.append({"name": f"accuracy/{net}/float_top1_pct",
                     "value": f"{rec['float_top1'] * 100:.2f}",
                     "paper": None})
        rows.append({"name": f"accuracy/{net}/int8_top1_pct",
                     "value": f"{rec['int8_top1'] * 100:.2f}",
                     "paper": None})
        rows.append({"name": f"accuracy/{net}/delta_points",
                     "value": f"{delta:.2f}", "paper": None})
        rows.append({"name": f"accuracy/{net}/int8_within_2pct_of_float",
                     "value": "PASS" if delta <= data["gate_points"]
                     else f"FAIL({delta:.2f}pts)",
                     "paper": "PASS"})
        rows.append({"name": f"accuracy/{net}/pallas_spotcheck_bit_identical",
                     "value": str(rec["pallas_spotcheck_bit_identical"]),
                     "paper": "True"})
    return rows
