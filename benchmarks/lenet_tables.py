"""Paper-table benchmarks (deliverable (d)) — one function per table/claim.

§5.1 functional-simulator table : GeMM loops (2942), DRAM traffic.
§5.2 cycle-accurate table       : TensorGemm cycles (2972), total compute
                                  cycles, execution time @650 MHz, SIMD-CPU
                                  comparison (47552 cycles, ≈10 GHz).
Compiler-throughput table       : wall-time to compile LeNet-5 end-to-end
                                  (the paper's pipeline is host-side Python;
                                  this measures OUR implementation).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.cycle_model import FPGA_CLOCK_HZ
from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)

PAPER = {
    "gemm_loops": 2942,
    "tensor_gemm_cycles": 2972,
    "total_cycles": 6358,
    "exec_us": 9.8,
    "simd_cpu_cycles": 47552,
    "cpu_clock_ghz": 10.0,
}


def _network(seed: int = 0):
    return compile_network(lenet5_specs(lenet5_random_weights(seed)),
                           synthetic_digit(seed))


def gemm_loops_table() -> List[Dict]:
    """Per-layer + total GeMM loops (paper reports the 2942 total)."""
    net = _network()
    rows = []
    for layer, loops in zip(net.layers, net.gemm_loops_per_layer()):
        rows.append({"name": f"gemm_loops/{layer.spec.name}",
                     "value": loops, "paper": None})
    rows.append({"name": "gemm_loops/total", "value": net.gemm_loops(),
                 "paper": PAPER["gemm_loops"]})
    return rows


def cycle_table() -> List[Dict]:
    net = _network()
    cr = net.cycle_report()
    return [
        {"name": "cycles/tensor_gemm", "value": cr.tensor_gemm_cycles,
         "paper": PAPER["tensor_gemm_cycles"]},
        {"name": "cycles/total_compute", "value": cr.total_compute_cycles,
         "paper": PAPER["total_cycles"],
         "note": "ours leaner ALU schedule (fused pool-div+requant)"},
        {"name": "exec_us@650MHz",
         "value": round(cr.execution_time_s(FPGA_CLOCK_HZ) * 1e6, 2),
         "paper": PAPER["exec_us"]},
        {"name": "simd_cpu_cycles", "value": cr.simd_cpu_cycles(16),
         "paper": PAPER["simd_cpu_cycles"]},
        {"name": "equiv_cpu_clock_ghz",
         "value": round(cr.equivalent_cpu_clock_hz() / 1e9, 1),
         "paper": PAPER["cpu_clock_ghz"]},
    ]


def dram_traffic_table() -> List[Dict]:
    """§5.1: 'total size of data exchanged with DRAM'."""
    net = _network()
    _, reports = net.run_functional()
    total_rd = sum(r.dram_bytes_read for r in reports)
    total_wr = sum(r.dram_bytes_written for r in reports)
    return [
        {"name": "dram/bytes_read", "value": total_rd, "paper": None},
        {"name": "dram/bytes_written", "value": total_wr, "paper": None},
        {"name": "dram/bytes_total", "value": total_rd + total_wr,
         "paper": None},
    ]


def compile_time_table(repeats: int = 3) -> List[Dict]:
    times = []
    for i in range(repeats):
        t0 = time.perf_counter()
        _network(seed=i)
        times.append(time.perf_counter() - t0)
    return [{"name": "compile/lenet5_wall_s",
             "value": round(float(np.median(times)), 3), "paper": None}]


def simulator_throughput_table() -> List[Dict]:
    """Functional-simulator speed (the paper: 'almost instantaneously'),
    for both backends: the oracle interpreter and the vectorised fast path."""
    net = _network()
    rows: List[Dict] = []
    wall = {}
    for backend in ("oracle", "fast"):
        net.run_functional(check_chaining=False, backend=backend)  # warm up
        t0 = time.perf_counter()
        net.run_functional(check_chaining=False, backend=backend)
        dt = time.perf_counter() - t0
        wall[backend] = dt
        rows.append({"name": f"funcsim/{backend}/wall_s",
                     "value": round(dt, 4), "paper": None})
        rows.append({"name": f"funcsim/{backend}/gemm_loops_per_s",
                     "value": int(net.gemm_loops() / dt), "paper": None})
    rows.append({"name": "funcsim/fast_speedup_x",
                 "value": round(wall["oracle"] / wall["fast"], 1),
                 "paper": None})
    return rows


def all_tables() -> List[Dict]:
    rows = []
    rows += gemm_loops_table()
    rows += cycle_table()
    rows += dram_traffic_table()
    rows += compile_time_table()
    rows += simulator_throughput_table()
    return rows
