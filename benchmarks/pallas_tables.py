"""Pallas-backend serving benchmarks (``pallas/*`` rows, BENCH_pallas.json).

Kernel-vs-fast serving throughput for the wired backend (DESIGN.md §2):
LeNet-5 batched serving and resnet8 per-image serving, each executed on
both the fast simulator and the pallas backend, with a bit-identity check
riding along (a perf row from a diverging backend would be meaningless).
Off-TPU the kernel runs in interpret mode — reported in the row names, as
with ``kernel/*`` — so these are correctness-trajectory numbers on CPU
and real accelerator numbers on TPU.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np


def _mode() -> str:
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def _time_serve(fn, repeats: int = 3) -> float:
    fn()                                    # warm up (plans, kernel traces)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _lenet_section(batch: int = 8) -> Dict:
    from repro.core.network_compiler import compile_network
    from repro.models.lenet import lenet5_random_weights, lenet5_specs
    net = compile_network(lenet5_specs(lenet5_random_weights(seed=0)),
                          np.zeros((1, 1, 32, 32), np.int8))
    rng = np.random.default_rng(0)
    images = np.stack([rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
                       for _ in range(batch)])
    out_fast, _ = net.serve(images)
    out_pal, _ = net.serve(images, backend="pallas")
    dt_fast = _time_serve(lambda: net.serve(images))
    dt_pal = _time_serve(lambda: net.serve(images, backend="pallas"))
    return {"batch": batch,
            "fast_img_per_s": batch / dt_fast,
            "kernel_img_per_s": batch / dt_pal,
            "bit_identical": bool(np.array_equal(out_fast, out_pal))}


def _resnet8_section() -> Dict:
    from repro.models.resnet8 import compile_resnet8, synthetic_image
    net, _ = compile_resnet8()
    img = synthetic_image(0)
    out_fast = net.serve_one(img, backend="fast")
    out_pal = net.serve_one(img, backend="pallas")
    dt_fast = _time_serve(lambda: net.serve_one(img, backend="fast"))
    dt_pal = _time_serve(lambda: net.serve_one(img, backend="pallas"))
    return {"fast_img_per_s": 1.0 / dt_fast,
            "kernel_img_per_s": 1.0 / dt_pal,
            "bit_identical": bool(np.array_equal(out_fast, out_pal))}


def collect() -> Dict:
    return {"mode": _mode(),
            "lenet5": _lenet_section(),
            "resnet8": _resnet8_section()}


def all_tables(data: Dict) -> List[Dict]:
    mode = data["mode"]
    rows: List[Dict] = []
    for workload in ("lenet5", "resnet8"):
        sec = data[workload]
        rows.append({"name": f"pallas/{workload}/fast_img_per_s",
                     "value": round(sec["fast_img_per_s"], 2),
                     "paper": None, "note": ""})
        rows.append({"name": f"pallas/{workload}/{mode}_img_per_s",
                     "value": round(sec["kernel_img_per_s"], 2),
                     "paper": None,
                     "note": f"kernel-vs-fast "
                             f"{sec['kernel_img_per_s'] / sec['fast_img_per_s']:.2f}x"})
        rows.append({"name": f"pallas/{workload}/bit_identical",
                     "value": "PASS" if sec["bit_identical"] else "FAIL",
                     "paper": None,
                     "note": "OUT == fast simulator (saturate=False)"})
        if not sec["bit_identical"]:
            raise AssertionError(
                f"pallas backend diverged from the fast simulator on "
                f"{workload} — perf rows withheld (fail-loud)")
    return rows
