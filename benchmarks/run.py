"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One table per paper claim (§5.1 loops, §5.2 cycles, DRAM traffic, compiler
throughput, simulator throughput) + the graph-compiled resnet_tiny rows
(``graph/*``, DESIGN.md §Graph) + kernel micro-benches + the roofline
summary from the latest dry-run sweep.  Output: ``name,value,paper,derived``
CSV rows, with PASS/DIFF annotations against the paper's numbers; the
resnet_tiny measurements are additionally written to
``BENCH_resnet_tiny.json`` (a reproducible artifact, gitignored) so the
perf trajectory has machine-readable data points.
"""

from __future__ import annotations

import json
import pathlib
import sys


def main() -> None:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks import (cifar_tables, kernel_bench, lenet_tables,
                            resnet_tables, serving_tables)

    print("name,value,paper,derived/status")
    failures = 0

    def emit(row) -> None:
        nonlocal failures
        paper = row.get("paper")
        status = ""
        if paper is not None:
            exact = {"gemm_loops/total", "cycles/tensor_gemm",
                     "simd_cpu_cycles"}
            if row["name"] in exact:
                status = "PASS(exact)" if row["value"] == paper else \
                    f"FAIL(expected {paper})"
                if "FAIL" in status:
                    failures += 1
            else:
                status = row.get("note", "") or f"paper={paper}"
        print(f"{row['name']},{row['value']},"
              f"{paper if paper is not None else ''},{status}")

    # The established paper-claim tables print before the newer
    # collections run, so a failure there cannot swallow them.
    for row in lenet_tables.all_tables() + cifar_tables.all_tables():
        emit(row)
    resnet_data = resnet_tables.collect()
    pathlib.Path("BENCH_resnet_tiny.json").write_text(
        json.dumps(resnet_data, indent=2) + "\n")
    for row in (resnet_tables.all_tables(resnet_data)
                + serving_tables.all_tables()):
        emit(row)

    for row in kernel_bench.all_tables():
        print(f"{row['name']},{row['value']},,{row.get('derived', '')}")

    # roofline summary (prefer the final sweep, fall back to baseline)
    dry = pathlib.Path("experiments/final")
    if not (dry.exists() and any(dry.glob("*.json"))):
        dry = pathlib.Path("experiments/dryrun")
    if dry.exists() and any(dry.glob("*.json")):
        from benchmarks import roofline
        cells = roofline.load_all(str(dry))
        sp = [c for c in cells if c.mesh == "16x16"]
        for c in sorted(sp, key=lambda c: (c.arch, c.shape)):
            print(f"roofline/{c.arch}/{c.shape},"
                  f"{c.roofline_fraction:.3f},,bound={c.dominant}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
