"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One table per paper claim (§5.1 loops, §5.2 cycles, DRAM traffic, compiler
throughput, simulator throughput) + the graph-compiled resnet_tiny rows
(``graph/*``, DESIGN.md §Graph) + the strided/GAP resnet8 rows
(``resnet8/*``, DESIGN.md §Strided-lowering) + kernel micro-benches + the
roofline summary from the latest dry-run sweep.  Output:
``name,value,paper,derived`` CSV rows, with PASS/DIFF annotations against
the paper's numbers; the resnet_tiny / resnet8 / pallas-backend /
serving-latency measurements are additionally written to
``BENCH_resnet_tiny.json`` / ``BENCH_resnet8.json`` /
``BENCH_pallas.json`` / ``BENCH_serving.json`` / ``BENCH_accuracy.json``
(reproducible artifacts, gitignored) so the perf trajectory has
machine-readable data points.

Hardening (the CI contract):

* a section that raises does not silently vanish — it prints an
  ``<section>/ERROR`` row with the exception and the process exits
  non-zero, so a broken table can never disappear from the artifacts;
* ``--only <prefix>`` runs just the sections that can produce rows with
  that prefix (and filters the printed rows to it) — the CI smoke step
  runs ``--only resnet8/`` without paying for every other table.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback


def _lenet_rows():
    from benchmarks import lenet_tables
    return lenet_tables.all_tables()


def _cifar_rows():
    from benchmarks import cifar_tables
    return cifar_tables.all_tables()


def _resnet_tiny_rows():
    from benchmarks import resnet_tables
    data = resnet_tables.collect()
    pathlib.Path("BENCH_resnet_tiny.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return resnet_tables.all_tables(data)


def _resnet8_rows():
    from benchmarks import resnet8_tables
    data = resnet8_tables.collect()
    pathlib.Path("BENCH_resnet8.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return resnet8_tables.all_tables(data)


def _serving_rows():
    from benchmarks import serving_tables
    return serving_tables.all_tables()


def _servelat_rows():
    from benchmarks import serving_latency_tables
    data = serving_latency_tables.collect()
    pathlib.Path("BENCH_serving.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return serving_latency_tables.all_tables(data)


def _kernel_rows():
    from benchmarks import kernel_bench
    return [{"name": row["name"], "value": row["value"], "paper": None,
             "note": row.get("derived", "")}
            for row in kernel_bench.all_tables()]


def _pallas_rows():
    from benchmarks import pallas_tables
    data = pallas_tables.collect()
    pathlib.Path("BENCH_pallas.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return pallas_tables.all_tables(data)


def _faults_rows():
    from benchmarks import fault_campaign
    data = fault_campaign.collect()
    pathlib.Path("BENCH_faults.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return fault_campaign.all_tables(data)


def _accuracy_rows():
    from benchmarks import accuracy_tables
    data = accuracy_tables.collect()
    pathlib.Path("BENCH_accuracy.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return accuracy_tables.all_tables(data)


def _pipeline_rows():
    from benchmarks import pipeline_tables
    data = pipeline_tables.collect()
    pathlib.Path("BENCH_pipeline.json").write_text(
        json.dumps(data, indent=2) + "\n")
    return pipeline_tables.all_tables(data)


def _roofline_rows():
    # roofline summary (prefer the final sweep, fall back to baseline)
    dry = pathlib.Path("experiments/final")
    if not (dry.exists() and any(dry.glob("*.json"))):
        dry = pathlib.Path("experiments/dryrun")
    if not (dry.exists() and any(dry.glob("*.json"))):
        return []
    from benchmarks import roofline
    cells = roofline.load_all(str(dry))
    sp = [c for c in cells if c.mesh == "16x16"]
    return [{"name": f"roofline/{c.arch}/{c.shape}",
             "value": f"{c.roofline_fraction:.3f}", "paper": None,
             "note": f"bound={c.dominant}"}
            for c in sorted(sp, key=lambda c: (c.arch, c.shape))]


# (section name, row-name prefixes it can produce, row producer).  The
# paper-claim tables print first so a failure in a newer collection can
# never swallow them.
SECTIONS = (
    ("lenet", ("gemm_loops/", "cycles/", "dram/", "exec_", "equiv_",
               "simd_", "compile/", "funcsim/"), _lenet_rows),
    ("cifar", ("cifar/",), _cifar_rows),
    ("resnet_tiny", ("graph/", "serve/resnet_tiny/"), _resnet_tiny_rows),
    ("resnet8", ("resnet8/",), _resnet8_rows),
    ("serving", ("serve/",), _serving_rows),
    ("servelat", ("servelat/",), _servelat_rows),
    ("kernels", ("kernel/",), _kernel_rows),
    ("pallas", ("pallas/",), _pallas_rows),
    ("faults", ("faults/",), _faults_rows),
    ("pipeline", ("pipeline/",), _pipeline_rows),
    ("accuracy", ("accuracy/",), _accuracy_rows),
    ("roofline", ("roofline/",), _roofline_rows),
)

# Rows whose paper column must match bit-for-bit (the §5 claims, plus the
# §Hardening zero-silent-data-corruption contract).
EXACT_ROWS = {"gemm_loops/total", "cycles/tensor_gemm", "simd_cpu_cycles",
              "faults/lenet5/sdc_total", "faults/resnet8/sdc_total",
              "pipeline/resnet8/makespan_reduction_ge_15pct",
              "servelat/lenet5/bit_identity",
              "servelat/resnet8/bit_identity",
              "servelat/lenet5/deterministic_replay",
              "servelat/resnet8/deterministic_replay",
              "accuracy/lenet5/int8_within_2pct_of_float",
              "accuracy/resnet8/int8_within_2pct_of_float",
              "accuracy/lenet5/pallas_spotcheck_bit_identical",
              "accuracy/resnet8/pallas_spotcheck_bit_identical"}


def _section_matches(prefixes, only: str) -> bool:
    """Could this section produce a row starting with ``only``?"""
    return any(p.startswith(only) or only.startswith(p) for p in prefixes)


def main(argv=None) -> None:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    ap = argparse.ArgumentParser(
        description="paper-claim benchmark tables (CSV on stdout)")
    ap.add_argument("--only", metavar="PREFIX", default=None,
                    help="run only sections producing rows with this "
                         "name prefix (e.g. resnet8/) and print only "
                         "matching rows")
    args = ap.parse_args(argv)

    print("name,value,paper,derived/status")
    failures = 0

    def emit(row) -> None:
        nonlocal failures
        if args.only and not row["name"].startswith(args.only):
            return
        paper = row.get("paper")
        status = ""
        if paper is not None:
            if row["name"] in EXACT_ROWS:
                status = "PASS(exact)" if row["value"] == paper else \
                    f"FAIL(expected {paper})"
                if "FAIL" in status:
                    failures += 1
            else:
                status = row.get("note", "") or f"paper={paper}"
        elif row.get("note"):
            status = row["note"]
        print(f"{row['name']},{row['value']},"
              f"{paper if paper is not None else ''},{status}")

    for name, prefixes, produce in SECTIONS:
        if args.only and not _section_matches(prefixes, args.only):
            continue
        try:
            rows = produce()
        except Exception as exc:                    # noqa: BLE001
            # a failed table must be *visible* in the CSV and fatal to
            # the run — never silently missing from the artifacts (the
            # message is flattened so it cannot break the 4-column rows)
            traceback.print_exc(file=sys.stderr)
            msg = f"{type(exc).__name__}: {exc}".replace(",", ";")
            msg = " ".join(msg.split())
            print(f"{name}/ERROR,{msg},,FAIL(raised)")
            failures += 1
            continue
        for row in rows:
            emit(row)

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
