"""Pipeline-schedule benchmarks — serialized vs double-buffered
(DESIGN.md §Pipeline, EXPERIMENTS.md §Pipeline).

For lenet5 and resnet8 the same network is compiled twice — once with
``schedule="serialized"`` (the paper's one-chunk-at-a-time token chain)
and once with ``schedule="pipelined"`` (double-buffered LOAD/GEMM with
store overlap) — and both instruction streams are swept through the
three-module concurrent cycle model.  The rows report per-module busy
cycles, the concurrent makespan, the serialized-vs-pipelined execution
time at the 650 MHz paper clock, and the headline reduction.

The ``pipeline/resnet8/makespan_reduction_ge_15pct`` row is the PR's
acceptance gate: it must read ``yes`` (pipelining buys at least a 15 %
makespan reduction on resnet8) and is checked bit-for-bit by
``benchmarks.run`` via ``EXACT_ROWS``.

``collect()`` returns the measurements as a JSON-ready dict;
``benchmarks.run`` writes it to ``BENCH_pipeline.json``.  Every row
name starts with ``pipeline/`` so ``benchmarks.run --only pipeline/``
runs exactly this table (the CI smoke step).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import cycle_model

SCHEDULES = ("serialized", "pipelined")


def _lenet_programs(schedule: str):
    from repro.models.lenet import (
        lenet5_random_weights, lenet5_specs, synthetic_digit)
    from repro.core.network_compiler import compile_network
    net = compile_network(lenet5_specs(lenet5_random_weights()),
                          synthetic_digit(0), schedule=schedule)
    return [layer.program for layer in net.layers]


def _resnet8_programs(schedule: str):
    from repro.models.resnet8 import compile_resnet8
    net, _graph = compile_resnet8(schedule=schedule)
    return [layer.program for layer in net.layers]


_WORKLOADS = (("lenet5", _lenet_programs), ("resnet8", _resnet8_programs))


def _measure(build, schedule: str) -> Dict:
    t0 = time.perf_counter()
    programs = build(schedule)
    compile_s = time.perf_counter() - t0
    rep = cycle_model.simulate_programs(programs)
    exec_us = rep.makespan_cycles / cycle_model.FPGA_CLOCK_HZ * 1e6
    return {
        "schedule": schedule,
        "compile_wall_s": round(compile_s, 3),
        "schedules_used": sorted({p.schedule for p in programs}),
        "makespan_cycles": rep.makespan_cycles,
        "busy_cycles": dict(rep.busy_cycles),
        "wait_cycles": dict(rep.wait_cycles),
        "total_busy_cycles": rep.total_busy_cycles,
        "exec_us_at_650mhz": round(exec_us, 2),
    }


def collect() -> Dict:
    """One measurement pass → the shared dict behind the CSV rows and
    the ``BENCH_pipeline.json`` artifact."""
    data: Dict = {"workloads": {}}
    for name, build in _WORKLOADS:
        per = {s: _measure(build, s) for s in SCHEDULES}
        serial = per["serialized"]["makespan_cycles"]
        piped = per["pipelined"]["makespan_cycles"]
        per["makespan_reduction_pct"] = round(100.0 * (1 - piped / serial), 1)
        data["workloads"][name] = per
    r8 = data["workloads"]["resnet8"]
    data["resnet8_reduction_ge_15pct"] = (
        "yes" if r8["makespan_reduction_pct"] >= 15.0 else "no")
    return data


def all_tables(data: Dict = None) -> List[Dict]:
    data = data or collect()
    rows: List[Dict] = []
    for name, per in data["workloads"].items():
        for sched in SCHEDULES:
            m = per[sched]
            for module in cycle_model.MODULES:
                rows.append({
                    "name": f"pipeline/{name}/{sched}/busy/{module}",
                    "value": m["busy_cycles"].get(module, 0), "paper": None})
            rows.append({"name": f"pipeline/{name}/{sched}/makespan_cycles",
                         "value": m["makespan_cycles"], "paper": None})
            rows.append({"name": f"pipeline/{name}/{sched}/exec_us@650MHz",
                         "value": m["exec_us_at_650mhz"], "paper": None})
        rows.append({"name": f"pipeline/{name}/makespan_reduction_pct",
                     "value": per["makespan_reduction_pct"], "paper": None})
    rows.append({"name": "pipeline/resnet8/makespan_reduction_ge_15pct",
                 "value": data["resnet8_reduction_ge_15pct"],
                 "paper": "yes"})
    return rows
