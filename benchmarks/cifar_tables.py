"""CIFAR-10-scale CNN benchmarks — the multi-chunk workload (DESIGN.md §3).

No paper column here: the paper stops at LeNet-5 and only claims "strong
potential for scaling"; these rows document what the scaled pipeline
actually does — per-layer chunk counts, total GeMM loops, the
compute-module LOAD overhead the multi-chunk schedule adds, and fast-
backend serving throughput (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core.cycle_model import FPGA_CLOCK_HZ
from repro.core.network_compiler import compile_network
from repro.models.cifar_cnn import (calibrate_shifts,
                                    cifar_cnn_random_weights,
                                    cifar_cnn_specs, synthetic_cifar_image)


def _network(seed: int = 0):
    weights = cifar_cnn_random_weights(seed)
    shifts = calibrate_shifts(weights,
                              [synthetic_cifar_image(s) for s in range(1, 4)])
    return compile_network(cifar_cnn_specs(weights, shifts),
                           synthetic_cifar_image(seed))


def all_tables() -> List[Dict]:
    t0 = time.perf_counter()
    net = _network()
    compile_s = time.perf_counter() - t0
    rows: List[Dict] = []
    for layer, chunks, loops in zip(net.layers, net.chunks_per_layer(),
                                    net.gemm_loops_per_layer()):
        rows.append({"name": f"cifar/chunks/{layer.spec.name}",
                     "value": chunks, "paper": None})
        rows.append({"name": f"cifar/gemm_loops/{layer.spec.name}",
                     "value": loops, "paper": None})
    rows.append({"name": "cifar/gemm_loops/total", "value": net.gemm_loops(),
                 "paper": None})
    cr = net.cycle_report()
    rows.append({"name": "cifar/cycles/total_compute",
                 "value": cr.total_compute_cycles, "paper": None})
    rows.append({"name": "cifar/cycles/compute_loads",
                 "value": cr.compute_load_cycles, "paper": None})
    rows.append({"name": "cifar/exec_us@650MHz",
                 "value": round(cr.execution_time_s(
                     FPGA_CLOCK_HZ, include_loads=True) * 1e6, 2),
                 "paper": None})
    rows.append({"name": "cifar/compile_wall_s",
                 "value": round(compile_s, 3), "paper": None})
    net.run_functional(check_chaining=False, backend="fast")   # warm plans
    t0 = time.perf_counter()
    net.run_functional(check_chaining=False, backend="fast")
    dt = time.perf_counter() - t0
    rows.append({"name": "cifar/funcsim/fast/wall_s",
                 "value": round(dt, 4), "paper": None})
    rows.append({"name": "cifar/funcsim/fast/gemm_loops_per_s",
                 "value": int(net.gemm_loops() / dt), "paper": None})
    return rows
